//! Serve mode: request router + dynamic batcher over a quantized model.
//!
//! The paper's formats are motivated by serving economics (memory-bound
//! weight-only quantization); this module is the runnable demonstration: a
//! next-token scoring service where client threads submit prompts, a
//! batcher coalesces them into fixed-`B` executions of the bound quantized
//! executable, and a router fans responses back. The dynamic-batching win
//! is measured by `perf_serve` (EXPERIMENTS.md §Perf).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::model::LmHandle;
use crate::tensor::Tensor;

/// One scoring request: a prompt (<= seq tokens); response = distribution
/// over the next token (top-1 id + logprob here).
pub struct Request {
    pub prompt: Vec<i32>,
    pub resp: mpsc::Sender<Response>,
    pub submitted: Instant,
}

/// Next-token prediction for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub next_token: i32,
    pub logprob: f32,
    pub latency: Duration,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// max time the batcher waits to fill a batch
    pub max_wait: Duration,
    /// stop serving after this many requests (0 = run until channel closes)
    pub max_requests: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_wait: Duration::from_millis(2), max_requests: 0 }
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub p50_latency: Duration,
    pub p99_latency: Duration,
    pub mean_batch_fill: f64,
}

/// The server: owns the handle; `run` consumes a request channel.
pub struct Server {
    handle: LmHandle,
    cfg: ServeConfig,
}

impl Server {
    pub fn new(handle: LmHandle, cfg: ServeConfig) -> Server {
        Server { handle, cfg }
    }

    /// Serve until the channel closes (or `max_requests`); returns stats.
    pub fn run(&mut self, rx: mpsc::Receiver<Request>) -> Result<ServeStats> {
        let b = self.handle.cfg.batch_eval;
        let s = self.handle.cfg.seq;
        let mut latencies: Vec<Duration> = Vec::new();
        let mut fills: Vec<usize> = Vec::new();
        let mut batches = 0usize;
        let mut served = 0usize;

        'outer: loop {
            // block for the first request of a batch
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + self.cfg.max_wait;
            while batch.len() < b {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        if batch.is_empty() {
                            break 'outer;
                        }
                        break;
                    }
                }
            }

            // marshal: left-pad short prompts into fixed [B, S]
            let mut tokens = vec![0i32; b * s];
            let mut cue = vec![0usize; batch.len()];
            for (r, req) in batch.iter().enumerate() {
                let p = &req.prompt;
                let n = p.len().min(s);
                tokens[r * s..r * s + n].copy_from_slice(&p[p.len() - n..]);
                cue[r] = n - 1;
            }
            let logits = self.handle.forward(&tokens)?;
            let logp = log_softmax_rows(&logits);
            for (r, req) in batch.iter().enumerate() {
                let row = logp.row(r * s + cue[r]);
                let best = crate::tensor::argmax(row);
                let latency = req.submitted.elapsed();
                latencies.push(latency);
                let _ = req.resp.send(Response {
                    next_token: best as i32,
                    logprob: row[best],
                    latency,
                });
            }
            served += batch.len();
            fills.push(batch.len());
            batches += 1;
            if self.cfg.max_requests > 0 && served >= self.cfg.max_requests {
                break;
            }
        }

        latencies.sort();
        let pick = |q: f64| {
            latencies
                .get(((latencies.len() as f64 * q) as usize).min(latencies.len().saturating_sub(1)))
                .copied()
                .unwrap_or_default()
        };
        Ok(ServeStats {
            served,
            batches,
            p50_latency: pick(0.50),
            p99_latency: pick(0.99),
            mean_batch_fill: fills.iter().sum::<usize>() as f64 / fills.len().max(1) as f64,
        })
    }
}

fn log_softmax_rows(logits: &Tensor) -> Tensor {
    logits.log_softmax_last()
}

/// Drive a server with `n_clients` synthetic clients issuing `per_client`
/// requests each; returns the server stats (used by the example + bench).
pub fn run_loadgen(
    mut server: Server,
    prompts: Vec<Vec<i32>>,
    n_clients: usize,
    per_client: usize,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();
    let prompts = Arc::new(prompts);
    let stats = Arc::new(Mutex::new(None));
    let stats2 = stats.clone();
    std::thread::scope(|scope| -> Result<()> {
        let server_thread = scope.spawn(move || {
            let st = server.run(rx);
            *stats2.lock().unwrap() = Some(st);
        });
        for c in 0..n_clients {
            let tx = tx.clone();
            let prompts = prompts.clone();
            scope.spawn(move || {
                for i in 0..per_client {
                    let (rtx, rrx) = mpsc::channel();
                    let prompt = prompts[(c * per_client + i) % prompts.len()].clone();
                    if tx
                        .send(Request { prompt, resp: rtx, submitted: Instant::now() })
                        .is_err()
                    {
                        return;
                    }
                    let _ = rrx.recv();
                }
            });
        }
        drop(tx);
        server_thread.join().unwrap();
        Ok(())
    })?;
    let st = stats.lock().unwrap().take().expect("server finished");
    st
}
