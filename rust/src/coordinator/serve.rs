//! Serve mode — now a thin compatibility shim over the continuous-batching
//! decode engine in [`crate::serving`].
//!
//! The original module was a fixed-`B` dynamic batcher doing one-shot
//! next-token scoring through the bound XLA executable. The public surface
//! ([`Request`] -> [`Response`], [`ServeConfig`], [`ServeStats`],
//! [`run_loadgen`]) is preserved, but requests are translated into
//! single-token [`DecodeRequest`]s on a [`serving::Engine`], which runs the
//! pure-Rust `nn` path over an fp32 or fake-quant checkpoint
//! (`coordinator::pipeline::fake_quant_checkpoint`). Multi-token clients
//! should use `serving::Engine` directly (`repro serve-decode`); this shim
//! exists so the historical scoring workload and its benchmarks keep
//! running. Empty prompts are now rejected (the old marshaller underflowed
//! on `prompt.len() == 0`); rejected clients see their response channel
//! close without a [`Response`].

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model_io::{Checkpoint, ModelConfig};
use crate::obs::clock;
use crate::serving::{
    next_request_id, percentile_sorted, DecodeRequest, Engine, EngineConfig, SchedulerConfig,
    TokenEvent,
};

/// One scoring request: a prompt (<= seq tokens); response = distribution
/// over the next token (top-1 id + logprob here).
pub struct Request {
    pub prompt: Vec<i32>,
    pub resp: mpsc::Sender<Response>,
    pub submitted: Instant,
}

/// Next-token prediction for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub next_token: i32,
    pub logprob: f32,
    pub latency: Duration,
}

/// Batching policy (generalized by `serving::SchedulerConfig`; kept for the
/// scoring shim's callers).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// max time the batcher waits to fill a batch
    pub max_wait: Duration,
    /// stop serving after this many requests (0 = run until channel closes)
    pub max_requests: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_wait: Duration::from_millis(2), max_requests: 0 }
    }
}

/// Aggregate serving statistics. `batches` counts engine steps;
/// `mean_batch_fill` is the engine's mean batch occupancy; `fused_gemms`
/// counts the fused `[B, d]` GEMM launches the engine issued on our behalf
/// (the scoring shim rides the same batched decode path as `serve-decode`).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub p50_latency: Duration,
    pub p99_latency: Duration,
    pub mean_batch_fill: f64,
    pub fused_gemms: u64,
}

/// The server: a scoring facade over the decode engine.
pub struct Server {
    engine: Engine,
    cfg: ServeConfig,
}

impl Server {
    /// Build from a model config + (fp32 or fake-quant) checkpoint. The
    /// engine's batch cap mirrors the model's `batch_eval`, like the old
    /// fixed-`B` batcher.
    pub fn new(model_cfg: ModelConfig, ckpt: Checkpoint, cfg: ServeConfig) -> Server {
        let batch = model_cfg.batch_eval.max(1);
        let engine = Engine::new(
            model_cfg,
            ckpt,
            EngineConfig {
                slots: batch,
                scheduler: SchedulerConfig {
                    max_batch: batch,
                    max_wait: cfg.max_wait,
                    ..SchedulerConfig::default()
                },
                ..EngineConfig::default()
            },
        );
        Server { engine, cfg }
    }

    /// Serve until the channel closes (or `max_requests`); returns stats.
    pub fn run(&mut self, rx: mpsc::Receiver<Request>) -> Result<ServeStats> {
        let (etx, erx) = mpsc::channel::<TokenEvent>();
        let (dtx, drx) = mpsc::channel::<DecodeRequest>();
        let registry: Arc<Mutex<HashMap<u64, (mpsc::Sender<Response>, Instant)>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let max_requests = self.cfg.max_requests;
        let engine = &mut self.engine;
        let engine_dead = Arc::new(std::sync::atomic::AtomicBool::new(false));

        std::thread::scope(|scope| -> Result<ServeStats> {
            // forwarder: old Request -> single-token DecodeRequest. Polls so
            // it can also exit when the engine dies mid-run (otherwise a
            // caller holding its Request sender open would pin the scope).
            let reg = registry.clone();
            let dead = engine_dead.clone();
            scope.spawn(move || {
                let mut forwarded = 0usize;
                loop {
                    let req = match rx.recv_timeout(Duration::from_millis(20)) {
                        Ok(r) => r,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if dead.load(std::sync::atomic::Ordering::Relaxed) {
                                break;
                            }
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    };
                    // reject empty prompts here: dropping the response sender
                    // closes the client's channel, and the request does not
                    // consume the max_requests budget (matching the old
                    // "served" accounting)
                    if req.prompt.is_empty() {
                        continue;
                    }
                    // ids come from the process-global allocator so trace
                    // tracks never collide with other engines' sessions;
                    // the max_requests budget is counted locally
                    let id = next_request_id();
                    forwarded += 1;
                    reg.lock().unwrap().insert(id, (req.resp, req.submitted));
                    let fwd = DecodeRequest {
                        id,
                        prompt: req.prompt,
                        max_new_tokens: 1,
                        eos: None,
                        events: etx.clone(),
                        submitted: req.submitted,
                        deadline: None,
                    };
                    if dtx.send(fwd).is_err() {
                        break;
                    }
                    if max_requests > 0 && forwarded >= max_requests {
                        break;
                    }
                }
                // dropping rx/dtx/etx here closes the pipeline end to end
            });

            // collector: first streamed token -> Response
            let reg = registry.clone();
            let collector = scope.spawn(move || {
                let mut latencies: Vec<Duration> = Vec::new();
                let mut served = 0usize;
                while let Ok(ev) = erx.recv() {
                    match ev {
                        TokenEvent::Token { request, token, logprob, .. } => {
                            if let Some((resp, submitted)) = reg.lock().unwrap().remove(&request)
                            {
                                let latency =
                                    clock::now().saturating_duration_since(submitted);
                                latencies.push(latency);
                                served += 1;
                                let _ = resp.send(Response {
                                    next_token: token,
                                    logprob,
                                    latency,
                                });
                            }
                        }
                        TokenEvent::Rejected { request, .. } => {
                            // drop the response sender: the client's recv errors
                            reg.lock().unwrap().remove(&request);
                        }
                        TokenEvent::Finished { .. } => {}
                    }
                }
                (latencies, served)
            });

            let run_res = engine.run(drx);
            if run_res.is_err() {
                // unblock everything: the forwarder's poll loop sees the
                // flag, terminal events cover in-flight work, and dropping
                // registered response senders releases waiting clients
                engine_dead.store(true, std::sync::atomic::Ordering::Relaxed);
                engine.abort();
                registry.lock().unwrap().clear();
            }
            let (mut latencies, served) = collector.join().expect("collector panicked");
            let report = run_res?;
            // sort once, take every percentile from the sorted slice
            latencies.sort_unstable();
            Ok(ServeStats {
                served,
                batches: report.steps,
                p50_latency: percentile_sorted(&latencies, 0.50),
                p99_latency: percentile_sorted(&latencies, 0.99),
                mean_batch_fill: report.mean_occupancy,
                fused_gemms: report.fused_gemms,
            })
        })
    }
}

/// Drive a server with `n_clients` synthetic clients issuing `per_client`
/// requests each; returns the server stats (used by the example + bench).
pub fn run_loadgen(
    mut server: Server,
    prompts: Vec<Vec<i32>>,
    n_clients: usize,
    per_client: usize,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();
    let prompts = Arc::new(prompts);
    let stats = Arc::new(Mutex::new(None));
    let stats2 = stats.clone();
    std::thread::scope(|scope| -> Result<()> {
        let server_thread = scope.spawn(move || {
            let st = server.run(rx);
            *stats2.lock().unwrap() = Some(st);
        });
        for c in 0..n_clients {
            let tx = tx.clone();
            let prompts = prompts.clone();
            scope.spawn(move || {
                for i in 0..per_client {
                    let (rtx, rrx) = mpsc::channel();
                    let prompt = prompts[(c * per_client + i) % prompts.len()].clone();
                    if tx
                        .send(Request { prompt, resp: rtx, submitted: clock::now() })
                        .is_err()
                    {
                        return;
                    }
                    let _ = rrx.recv();
                }
            });
        }
        drop(tx);
        server_thread.join().unwrap();
        Ok(())
    })?;
    let st = stats.lock().unwrap().take().expect("server finished");
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::init_lm_params;
    use crate::model_io::zoo;

    fn server(cfg: ServeConfig) -> Server {
        let mc = zoo("nano").unwrap();
        Server::new(mc, init_lm_params(&mc, 11), cfg)
    }

    fn prompts(n: usize) -> Vec<Vec<i32>> {
        (0..n as i32).map(|s| vec![s + 1, s + 2, s + 3, s + 4]).collect()
    }

    #[test]
    fn serves_every_client_and_reports_fill() {
        let st = run_loadgen(server(ServeConfig::default()), prompts(8), 4, 4).unwrap();
        assert_eq!(st.served, 16);
        assert!(st.batches >= 1);
        assert!(st.mean_batch_fill >= 1.0);
        assert!(st.fused_gemms > 0, "scoring rides the fused batched decode path");
        assert!(st.p50_latency <= st.p99_latency);
    }

    #[test]
    fn max_requests_boundary_stops_exactly_there() {
        // 6 requests offered, cap at 4: exactly 4 served, the rest see their
        // response channels close instead of hanging
        let st = run_loadgen(
            server(ServeConfig { max_requests: 4, ..ServeConfig::default() }),
            prompts(6),
            1,
            6,
        )
        .unwrap();
        assert_eq!(st.served, 4);
    }

    #[test]
    fn channel_close_with_no_requests_returns_empty_stats() {
        let mut srv = server(ServeConfig::default());
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let st = srv.run(rx).unwrap();
        assert_eq!(st.served, 0);
        assert_eq!(st.batches, 0);
        assert_eq!(st.p50_latency, Duration::ZERO);
        assert_eq!(st.p99_latency, Duration::ZERO);
    }

    #[test]
    fn empty_prompt_is_rejected_without_panicking() {
        // the old marshaller computed `cue = n - 1` and underflowed here
        let mut srv = server(ServeConfig::default());
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request { prompt: vec![], resp: rtx, submitted: clock::now() }).unwrap();
        let (rtx2, rrx2) = mpsc::channel();
        tx.send(Request { prompt: vec![1, 2], resp: rtx2, submitted: clock::now() }).unwrap();
        drop(tx);
        let st = srv.run(rx).unwrap();
        assert_eq!(st.served, 1, "only the valid request is served");
        assert!(rrx.recv().is_err(), "rejected client's channel closes");
        assert!(rrx2.recv().is_ok());
    }

    #[test]
    fn responses_carry_finite_logprobs_and_latency() {
        let mc = zoo("nano").unwrap();
        let mut srv = Server::new(mc, init_lm_params(&mc, 12), ServeConfig::default());
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request { prompt: vec![3, 1, 4], resp: rtx, submitted: clock::now() })
            .unwrap();
        drop(tx);
        srv.run(rx).unwrap();
        let resp = rrx.recv().unwrap();
        assert!(resp.next_token >= 0 && (resp.next_token as usize) < mc.vocab);
        assert!(resp.logprob.is_finite() && resp.logprob <= 0.0);
        assert!(resp.latency > Duration::ZERO);
    }
}
