//! The PTQ pipeline: checkpoint -> artifact-ready quantized parameter set.
//!
//! Mirrors the paper's evaluation stack: RTN or GPTQ rounding, absmax or
//! MSE-clip scales, sub-channel blocks (16..256 or channelwise), optional
//! SmoothQuant for W4A4, all over any codebook in the zoo. The output is a
//! named `Value` map that plugs directly into the `lm_fwd*` / `lm_loss*`
//! artifacts (codes i8 + expanded scales + the 16-entry codebook).

use std::collections::HashMap;

use anyhow::Result;

use crate::data::Corpus;
use crate::formats::{self, FormatSpec};
use crate::model_io::{Checkpoint, ModelConfig};
use crate::nn;
use crate::quant::{
    gptq_quantize, quantize_weight, smooth_scales, BlockSize, Calib, GptqConfig, QuantConfig,
    SmoothQuant,
};
use crate::runtime::Value;
use crate::tensor::Tensor;

/// Rounding method (paper Table 6 compares RTN vs GPTQ).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMethod {
    Rtn,
    Gptq,
}

impl QuantMethod {
    pub fn label(&self) -> &'static str {
        match self {
            QuantMethod::Rtn => "RTN",
            QuantMethod::Gptq => "GPTQ",
        }
    }
}

/// Full pipeline configuration for one (model, format) cell.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub format: String,
    pub block: BlockSize,
    pub calib: Calib,
    pub method: QuantMethod,
    /// W4A4: also quantize activations in-graph with this codebook.
    pub act_format: Option<String>,
    /// SmoothQuant alpha (W4A4 only); None disables smoothing.
    pub smoothquant: Option<f64>,
    /// Calibration sequences (GPTQ / SmoothQuant).
    pub calib_seqs: usize,
}

impl PipelineConfig {
    pub fn weight_only(format: &str) -> Self {
        PipelineConfig {
            format: format.into(),
            block: BlockSize::Sub(128),
            calib: Calib::None,
            method: QuantMethod::Rtn,
            act_format: None,
            smoothquant: None,
            calib_seqs: 8,
        }
    }

    pub fn w4a4(format: &str, smoothquant: bool) -> Self {
        PipelineConfig {
            act_format: Some(format.into()),
            smoothquant: if smoothquant { Some(0.5) } else { None },
            ..PipelineConfig::weight_only(format)
        }
    }

    pub fn is_w4a4(&self) -> bool {
        self.act_format.is_some()
    }

    /// Resolve a block size that divides every quantized linear's K
    /// (sub-channel blocks must divide d_model and d_ff).
    fn resolved_block(&self, k: usize) -> BlockSize {
        match self.block {
            BlockSize::Sub(b) if k % b != 0 => BlockSize::Sub(k.min(b.min(k))),
            other => other,
        }
    }
}

/// The quantized parameter set for one model + stats.
pub struct QuantizedModel {
    /// Artifact inputs by name (everything except `tokens`).
    pub values: HashMap<String, Value>,
    pub spec: FormatSpec,
    /// Mean weight reconstruction MSE across quantized linears.
    pub recon_mse: f64,
    pub w4a4: bool,
}

/// Run the full pipeline on one LM checkpoint.
pub fn quantize_lm(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    pc: &PipelineConfig,
    corpus: &Corpus,
) -> Result<QuantizedModel> {
    let spec = formats::must(&pc.format);
    let qnames = cfg.quant_linear_names();

    // calibration activations: needed by GPTQ and SmoothQuant
    let needs_calib = pc.method == QuantMethod::Gptq || pc.smoothquant.is_some();
    let capture = if needs_calib {
        let windows = corpus.heldout_windows(pc.calib_seqs, cfg.seq);
        let seqs: Vec<Vec<i32>> =
            windows.iter().map(|w| w[..cfg.seq].to_vec()).collect();
        Some(nn::calibrate_lm(cfg, ckpt, &seqs, 2048)?)
    } else {
        None
    };

    let mut values: HashMap<String, Value> = HashMap::new();
    let mut mse_acc = 0.0f64;
    let mut mse_n = 0usize;

    for (name, _) in cfg.param_specs() {
        let t = ckpt.get(&name)?;
        if !qnames.contains(&name) {
            values.insert(name.clone(), Value::F32(t.clone()));
            continue;
        }
        let k = t.rows();
        // SmoothQuant: scale weights up where activations have outliers
        let smooth = match (pc.smoothquant, &capture) {
            (Some(alpha), Some(cap)) => {
                let x = cap
                    .stacked(&name)
                    .ok_or_else(|| anyhow::anyhow!("no calibration acts for {name}"))?;
                smooth_scales(&x, t, alpha)
            }
            _ => SmoothQuant::identity(k),
        };
        let w = smooth.apply_to_weight(t);

        let qcfg = QuantConfig {
            format: spec.clone(),
            block: pc.resolved_block(k),
            calib: pc.calib,
        };
        let q = match pc.method {
            QuantMethod::Rtn => quantize_weight(&w, &qcfg),
            QuantMethod::Gptq => {
                let cap = capture.as_ref().expect("gptq needs calibration");
                let mut x = cap
                    .stacked(&name)
                    .ok_or_else(|| anyhow::anyhow!("no calibration acts for {name}"))?;
                // GPTQ sees the smoothed inputs (x / s)
                for r in 0..x.rows() {
                    let row = x.row_mut(r);
                    for (j, v) in row.iter_mut().enumerate() {
                        *v *= smooth.inv_smooth[j];
                    }
                }
                gptq_quantize(&w, &x, &qcfg, &GptqConfig::default())
            }
        };
        mse_acc += w.sq_err(&q.dequant(&spec)) / w.len() as f64;
        mse_n += 1;

        values.insert(format!("{name}.codes"), Value::I8(q.codes.clone(), vec![q.k, q.n]));
        values.insert(format!("{name}.scales"), Value::F32(q.expanded_scales()));
        if pc.is_w4a4() {
            values.insert(
                format!("{name}.smooth"),
                Value::F32(Tensor::new(&[k], smooth.inv_smooth.clone())),
            );
        }
    }

    values.insert("codebook".into(), Value::F32(Tensor::new(&[16], spec.padded16())));
    if let Some(act_fmt) = &pc.act_format {
        let act_spec = formats::must(act_fmt);
        values
            .insert("act_codebook".into(), Value::F32(Tensor::new(&[16], act_spec.padded16())));
    }

    Ok(QuantizedModel {
        values,
        spec,
        recon_mse: mse_acc / mse_n.max(1) as f64,
        w4a4: pc.is_w4a4(),
    })
}

/// Weight-only quantization of every quant linear in a checkpoint: shared
/// core of [`fake_quant_checkpoint`] and [`packed_checkpoint`]. Refuses
/// W4A4/SmoothQuant configs — SmoothQuant folds an activation rescale into
/// the weights that the eval graph undoes on the activation side; the nn
/// reference path has no such hook, so silently applying (or dropping) it
/// would produce a model that matches neither the fp32 nor the W4A4
/// artifact.
fn quantize_serving_linears(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    pc: &PipelineConfig,
    corpus: &Corpus,
    caller: &str,
) -> Result<(FormatSpec, Vec<(String, crate::quant::QuantizedWeight)>)> {
    anyhow::ensure!(
        pc.smoothquant.is_none() && pc.act_format.is_none(),
        "{caller} supports weight-only configs (smoothquant/act_format must be None)"
    );
    let spec = formats::must(&pc.format);
    let capture = if pc.method == QuantMethod::Gptq {
        let windows = corpus.heldout_windows(pc.calib_seqs, cfg.seq);
        let seqs: Vec<Vec<i32>> = windows.iter().map(|w| w[..cfg.seq].to_vec()).collect();
        Some(nn::calibrate_lm(cfg, ckpt, &seqs, 2048)?)
    } else {
        None
    };
    let mut out = Vec::new();
    for name in cfg.quant_linear_names() {
        let t = ckpt.get(&name)?;
        let qcfg = QuantConfig {
            format: spec.clone(),
            block: pc.resolved_block(t.rows()),
            calib: pc.calib,
        };
        let q = match pc.method {
            QuantMethod::Rtn => quantize_weight(t, &qcfg),
            QuantMethod::Gptq => {
                let x = capture
                    .as_ref()
                    .expect("gptq needs calibration")
                    .stacked(&name)
                    .ok_or_else(|| anyhow::anyhow!("no calibration acts for {name}"))?;
                gptq_quantize(t, &x, &qcfg, &GptqConfig::default())
            }
        };
        out.push((name, q));
    }
    Ok((spec, out))
}

/// Run the weight pipeline but keep the result as an nn-compatible
/// [`Checkpoint`]: every quantized linear is replaced by its dequantized
/// (fake-quant) reconstruction, all other tensors pass through. This is the
/// dense serving weight path — `nn::forward_lm_step` consumes the result
/// unchanged, so the decode loop exercises exactly the codebook the
/// `formats`/`quant` stack produced.
pub fn fake_quant_checkpoint(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    pc: &PipelineConfig,
    corpus: &Corpus,
) -> Result<Checkpoint> {
    let (spec, qs) =
        quantize_serving_linears(cfg, ckpt, pc, corpus, "fake_quant_checkpoint")?;
    let qmap: HashMap<String, crate::quant::QuantizedWeight> = qs.into_iter().collect();
    let mut out = Checkpoint::new();
    for (name, _) in cfg.param_specs() {
        match qmap.get(&name) {
            Some(q) => out.insert(&name, q.dequant(&spec)),
            None => out.insert(&name, ckpt.get(&name)?.clone()),
        }
    }
    Ok(out)
}

/// Run the weight pipeline and keep every quantized linear at its true
/// 4-bit footprint: codes packed two-per-byte plus per-block scales
/// ([`crate::quant::PackedWeight`]), dispatched at forward time through the
/// fused `quant::lut_gemm` (`nn::apply_linear`) — the serving engine
/// decodes without ever materializing f32 weights for these linears. All
/// other tensors pass through dense. Forward results are bit-identical to
/// the same config's [`fake_quant_checkpoint`] (the packed path expands
/// `lut[code] * scale` with the same f32 expression and the same blocked
/// kernel — `rust/tests/packed_weight.rs`). Weight-only configs with a
/// <= 16-value codebook only.
pub fn packed_checkpoint(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    pc: &PipelineConfig,
    corpus: &Corpus,
) -> Result<Checkpoint> {
    let (spec, qs) = quantize_serving_linears(cfg, ckpt, pc, corpus, "packed_checkpoint")?;
    anyhow::ensure!(
        spec.n_values() <= 16,
        "packed_checkpoint: `{}` has {} codebook values (> 4-bit)",
        spec.name,
        spec.n_values()
    );
    let qmap: HashMap<String, crate::quant::QuantizedWeight> = qs.into_iter().collect();
    let mut out = Checkpoint::new();
    for (name, _) in cfg.param_specs() {
        match qmap.get(&name) {
            Some(q) => {
                out.insert_packed(&name, crate::quant::PackedWeight::from_quantized(q, &spec))
            }
            None => out.insert(&name, ckpt.get(&name)?.clone()),
        }
    }
    Ok(out)
}

/// W4A4 serving checkpoint: the weight side is exactly
/// [`packed_checkpoint`] (packed codes + per-block scales per linear), plus
/// an installed [`crate::quant::ActQuantizer`] that upgrades every packed
/// linear to `LinearBackend::PackedW4a4` — `nn::apply_linear` then encodes
/// each activation tile to 4-bit codes (absmax blocks matching the
/// weight's) and multiplies code x code through `quant::w4a4_gemm`.
///
/// W4A4 changes numerics by design (the activations are quantized), so
/// unlike the packed weight-only path there is no bit-identity contract;
/// the accuracy gate is the Table-8-style NLL delta in
/// `rust/tests/simd_kernels.rs`. SmoothQuant configs are refused: the
/// smoothing fold needs an activation-side unscale hook the nn serving
/// forward does not have (the artifact graphs apply `{name}.smooth`).
pub fn w4a4_checkpoint(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    pc: &PipelineConfig,
    corpus: &Corpus,
) -> Result<Checkpoint> {
    let act_fmt = pc.act_format.as_deref().unwrap_or(&pc.format);
    anyhow::ensure!(
        pc.smoothquant.is_none(),
        "w4a4_checkpoint: SmoothQuant needs the artifact graphs' activation-side unscale; \
         use PipelineConfig::w4a4(fmt, false)"
    );
    let act_spec = formats::must(act_fmt);
    anyhow::ensure!(
        act_spec.n_values() <= 16,
        "w4a4_checkpoint: activation format `{}` has {} codebook values (> 4-bit)",
        act_spec.name,
        act_spec.n_values()
    );
    // weight side: the weight-only view of this config, packed verbatim
    let wpc = PipelineConfig { act_format: None, smoothquant: None, ..pc.clone() };
    let mut out = packed_checkpoint(cfg, ckpt, &wpc, corpus)?;
    out.set_act_quant(Some(crate::quant::ActQuantizer::new(&act_spec)));
    Ok(out)
}

/// fp32 "identity pipeline": artifact inputs for the fp32 eval graphs.
pub fn fp32_values(cfg: &ModelConfig, ckpt: &Checkpoint) -> Result<HashMap<String, Value>> {
    let mut values = HashMap::new();
    for (name, _) in cfg.param_specs() {
        values.insert(name.clone(), Value::F32(ckpt.get(&name)?.clone()));
    }
    Ok(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::corpus_for;
    use crate::model_io::zoo;
    use crate::rng::Pcg64;

    fn ckpt(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = Pcg64::new(seed);
        let mut c = Checkpoint::new();
        for (name, shape) in cfg.param_specs() {
            let n: usize = shape.iter().product();
            let leaf = name.rsplit('.').next().unwrap();
            let t = if leaf.ends_with("_g") {
                Tensor::full(&shape, 1.0)
            } else if leaf.ends_with("_b") {
                Tensor::zeros(&shape)
            } else {
                Tensor::new(&shape, rng.student_t_vec(n, 5.0, (1.0 / shape[0] as f64).sqrt()))
            };
            c.insert(&name, t);
        }
        c
    }

    #[test]
    fn weight_only_pipeline_produces_artifact_inputs() {
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 1);
        let corpus = corpus_for(&cfg);
        let qm = quantize_lm(&cfg, &c, &PipelineConfig::weight_only("sf4"), &corpus).unwrap();
        // every artifact input except tokens must be present
        for name in cfg.quant_linear_names() {
            assert!(qm.values.contains_key(&format!("{name}.codes")), "{name}");
            assert!(qm.values.contains_key(&format!("{name}.scales")), "{name}");
            assert!(!qm.values.contains_key(&format!("{name}.smooth")));
        }
        assert!(qm.values.contains_key("embed"));
        assert!(qm.values.contains_key("codebook"));
        assert!(!qm.values.contains_key("act_codebook"));
        assert!(qm.recon_mse > 0.0 && qm.recon_mse < 1.0);
    }

    #[test]
    fn w4a4_pipeline_adds_smooth_and_act_codebook() {
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 2);
        let corpus = corpus_for(&cfg);
        let qm = quantize_lm(&cfg, &c, &PipelineConfig::w4a4("e2m1", true), &corpus).unwrap();
        for name in cfg.quant_linear_names() {
            assert!(qm.values.contains_key(&format!("{name}.smooth")), "{name}");
        }
        assert!(qm.values.contains_key("act_codebook"));
        // smoothing vectors must be finite and positive
        for name in cfg.quant_linear_names() {
            let v = qm.values[&format!("{name}.smooth")].as_f32().unwrap();
            assert!(v.data().iter().all(|&x| x.is_finite() && x > 0.0));
        }
    }

    #[test]
    fn gptq_pipeline_runs_and_reduces_task_mse() {
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 3);
        let corpus = corpus_for(&cfg);
        let mut pc = PipelineConfig::weight_only("int4");
        let rtn = quantize_lm(&cfg, &c, &pc, &corpus).unwrap();
        pc.method = QuantMethod::Gptq;
        let gptq = quantize_lm(&cfg, &c, &pc, &corpus).unwrap();
        // GPTQ optimizes task error, not weight MSE, but on these sizes the
        // reconstruction should stay in the same ballpark.
        assert!(gptq.recon_mse < rtn.recon_mse * 10.0);
    }

    #[test]
    fn fake_quant_checkpoint_matches_value_path() {
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 5);
        let corpus = corpus_for(&cfg);
        let pc = PipelineConfig::weight_only("sf4");
        let fq = fake_quant_checkpoint(&cfg, &c, &pc, &corpus).unwrap();
        // same tensor inventory as the source checkpoint
        let names: Vec<String> = cfg.param_specs().into_iter().map(|(n, _)| n).collect();
        for name in &names {
            assert_eq!(fq.get(name).unwrap().shape(), c.get(name).unwrap().shape(), "{name}");
        }
        // quantized linears actually changed, non-quantized passed through
        for name in cfg.quant_linear_names() {
            assert!(fq.get(&name).unwrap() != c.get(&name).unwrap(), "{name} unquantized");
        }
        assert_eq!(fq.get("embed").unwrap(), c.get("embed").unwrap());
        // reconstruction agrees with the artifact-value pipeline's MSE scale
        let qm = quantize_lm(&cfg, &c, &pc, &corpus).unwrap();
        let mut mse = 0.0f64;
        let mut n = 0usize;
        for name in cfg.quant_linear_names() {
            let w = c.get(&name).unwrap();
            mse += w.sq_err(fq.get(&name).unwrap()) / w.len() as f64;
            n += 1;
        }
        let mse = mse / n as f64;
        assert!((mse - qm.recon_mse).abs() < 1e-9, "{mse} vs {}", qm.recon_mse);
    }

    #[test]
    fn packed_checkpoint_stores_linears_packed_and_rejects_wide_codebooks() {
        use crate::model_io::LinearBackend;
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 6);
        let corpus = corpus_for(&cfg);
        let pc = PipelineConfig::weight_only("sf4");
        let packed = packed_checkpoint(&cfg, &c, &pc, &corpus).unwrap();
        for name in cfg.quant_linear_names() {
            assert_eq!(packed.backend(&name), LinearBackend::Packed4, "{name}");
            assert!(packed.get(&name).is_err(), "{name}: no dense tensor materialized");
        }
        assert_eq!(packed.backend("embed"), LinearBackend::Dense);
        assert_eq!(packed.get("embed").unwrap(), c.get("embed").unwrap());
        assert_eq!(packed.packed_names().len(), cfg.quant_linear_names().len());
        // the packed store is a small fraction of the dense linears' bytes
        let dense_bytes: usize =
            cfg.quant_linear_names().iter().map(|n| c.get(n).unwrap().len() * 4).sum();
        assert!(packed.packed_bytes() * 3 < dense_bytes, "{}", packed.packed_bytes());
        // packed dequant reproduces the fake-quant tensors exactly
        let fq = fake_quant_checkpoint(&cfg, &c, &pc, &corpus).unwrap();
        for name in cfg.quant_linear_names() {
            let pd = packed.get_packed(&name).unwrap().dequant();
            assert_eq!(pd.data(), fq.get(&name).unwrap().data(), "{name}");
        }
        // int5 has 32 codebook values: cannot pack into nibbles
        assert!(packed_checkpoint(&cfg, &c, &PipelineConfig::weight_only("int5"), &corpus)
            .is_err());
        // W4A4 configs are refused like the fake-quant path
        assert!(packed_checkpoint(&cfg, &c, &PipelineConfig::w4a4("sf4", true), &corpus)
            .is_err());
    }

    #[test]
    fn w4a4_checkpoint_installs_act_quantizer_and_refuses_smoothquant() {
        use crate::model_io::LinearBackend;
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 7);
        let corpus = corpus_for(&cfg);
        let pc = PipelineConfig::w4a4("sf4", false);
        let w4a4 = w4a4_checkpoint(&cfg, &c, &pc, &corpus).unwrap();
        let aq = w4a4.act_quant().expect("activation quantizer installed");
        assert_eq!(aq.name, "sf4");
        for name in cfg.quant_linear_names() {
            assert_eq!(w4a4.backend(&name), LinearBackend::PackedW4a4, "{name}");
        }
        assert_eq!(w4a4.backend("embed"), LinearBackend::Dense);
        // weight side is bit-for-bit the weight-only packed checkpoint
        let packed =
            packed_checkpoint(&cfg, &c, &PipelineConfig::weight_only("sf4"), &corpus).unwrap();
        for name in cfg.quant_linear_names() {
            let (a, b) =
                (w4a4.get_packed(&name).unwrap(), packed.get_packed(&name).unwrap());
            assert_eq!(a.packed, b.packed, "{name}");
            assert_eq!(a.scales.data(), b.scales.data(), "{name}");
        }
        // nn dispatch runs the code x code path and stays close to the
        // weight-only packed output (activations only lose 4-bit precision)
        let name = &cfg.quant_linear_names()[0];
        let k = packed.get_packed(name).unwrap().k;
        let mut rng = Pcg64::new(0xac7);
        let x = Tensor::new(&[3, k], rng.normal_vec(3 * k, 1.0));
        let yq = nn::apply_linear(&w4a4, &x, name).unwrap();
        let yw = nn::apply_linear(&packed, &x, name).unwrap();
        assert_eq!(yq.shape(), yw.shape());
        let denom: f64 = yw.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>().max(1e-9);
        let err: f64 = yq
            .data()
            .iter()
            .zip(yw.data())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(err / denom < 0.05, "relative act-quant error too large: {}", err / denom);
        // SmoothQuant needs the artifact graphs' activation-side unscale
        assert!(w4a4_checkpoint(&cfg, &c, &PipelineConfig::w4a4("sf4", true), &corpus).is_err());
        // weight-only configs still work (act side defaults to the weight format)
        assert!(w4a4_checkpoint(&cfg, &c, &PipelineConfig::weight_only("e2m1"), &corpus).is_ok());
        // wide codebooks cannot feed the 16x16 product LUT
        assert!(w4a4_checkpoint(&cfg, &c, &PipelineConfig::weight_only("int5"), &corpus).is_err());
    }

    #[test]
    fn sf4_reconstruction_beats_int4_on_t_weights() {
        let cfg = zoo("nano").unwrap();
        let c = ckpt(&cfg, 4); // student-t weights
        let corpus = corpus_for(&cfg);
        let sf4 =
            quantize_lm(&cfg, &c, &PipelineConfig::weight_only("sf4"), &corpus).unwrap();
        let int4 =
            quantize_lm(&cfg, &c, &PipelineConfig::weight_only("int4"), &corpus).unwrap();
        assert!(sf4.recon_mse < int4.recon_mse, "{} vs {}", sf4.recon_mse, int4.recon_mse);
    }
}
