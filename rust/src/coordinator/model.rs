//! `LmHandle`: one model's eval executables with device-resident weights,
//! exposing the [`LmScorer`] interface the task suite consumes.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::model_io::ModelConfig;
use crate::runtime::{BoundInputs, Engine, Executable, Value};
use crate::tasks::LmScorer;
use crate::tensor::Tensor;

/// Which eval graphs to bind: fp32 baseline, weight-only, or W4A4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphKind {
    Fp32,
    WeightOnly,
    W4A4,
}

impl GraphKind {
    fn fwd_name(&self, model: &str) -> String {
        match self {
            GraphKind::Fp32 => format!("lm_fwd_fp32_{model}"),
            GraphKind::WeightOnly => format!("lm_fwd_{model}"),
            GraphKind::W4A4 => format!("lm_fwd_w4a4_{model}"),
        }
    }

    fn loss_name(&self, model: &str) -> String {
        match self {
            GraphKind::Fp32 => format!("lm_loss_fp32_{model}"),
            GraphKind::WeightOnly => format!("lm_loss_{model}"),
            GraphKind::W4A4 => format!("lm_loss_w4a4_{model}"),
        }
    }
}

/// A ready-to-eval model: compiled fwd/loss graphs + bound weight buffers.
pub struct LmHandle {
    pub cfg: ModelConfig,
    fwd: Executable,
    loss: Executable,
    fwd_bound: BoundInputs,
    loss_bound: BoundInputs,
    /// executions since construction (used by perf reporting)
    pub calls: std::cell::Cell<u64>,
}

impl LmHandle {
    /// Compile + bind. `values` must contain every input except `tokens`
    /// (from [`super::pipeline::quantize_lm`] or `fp32_values`).
    pub fn bind(
        engine: &Engine,
        cfg: &ModelConfig,
        kind: GraphKind,
        values: &HashMap<String, Value>,
    ) -> Result<LmHandle> {
        let fwd = engine
            .load(&kind.fwd_name(cfg.name))
            .with_context(|| format!("loading fwd graph for {}", cfg.name))?;
        let loss = engine.load(&kind.loss_name(cfg.name))?;
        let fwd_bound = fwd.bind(values)?;
        let loss_bound = loss.bind(values)?;
        anyhow::ensure!(
            fwd_bound.missing == vec!["tokens".to_string()],
            "fwd graph has unexpected unbound inputs: {:?}",
            fwd_bound.missing
        );
        Ok(LmHandle {
            cfg: *cfg,
            fwd,
            loss,
            fwd_bound,
            loss_bound,
            calls: std::cell::Cell::new(0),
        })
    }

    /// Raw forward: tokens `[B*S]` -> logits tensor `[B*S, V]`.
    pub fn forward(&self, tokens: &[i32]) -> Result<Tensor> {
        let (b, s, v) = (self.cfg.batch_eval, self.cfg.seq, self.cfg.vocab);
        anyhow::ensure!(tokens.len() == b * s, "bad token count {}", tokens.len());
        let mut rest = HashMap::new();
        rest.insert("tokens".to_string(), Value::I32(tokens.to_vec(), vec![b, s]));
        let outs = self.fwd.run_bound(&self.fwd_bound, &rest)?;
        self.calls.set(self.calls.get() + 1);
        let logits = outs[0].as_f32()?;
        Ok(logits.clone().reshape(&[b * s, v]))
    }
}

impl LmScorer for LmHandle {
    fn batch(&self) -> usize {
        self.cfg.batch_eval
    }

    fn seq(&self) -> usize {
        self.cfg.seq
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn logits(&mut self, tokens: &[i32]) -> Result<Tensor> {
        self.forward(tokens)
    }

    fn nll_sum(&mut self, tokens: &[i32]) -> Result<(f64, f64)> {
        let (b, s) = (self.cfg.batch_eval, self.cfg.seq);
        anyhow::ensure!(tokens.len() == b * (s + 1), "bad token count");
        let mut rest = HashMap::new();
        rest.insert("tokens".to_string(), Value::I32(tokens.to_vec(), vec![b, s + 1]));
        let outs = self.loss.run_bound(&self.loss_bound, &rest)?;
        self.calls.set(self.calls.get() + 1);
        Ok((outs[0].scalar_f32()? as f64, outs[1].scalar_f32()? as f64))
    }
}
