//! Experiment grid runner: a worker pool over (model x format x method)
//! cells. Quantization (GPTQ especially) is CPU-heavy Rust work that
//! parallelizes across cells; XLA executions serialize behind the PJRT lock
//! but overlap with other cells' quantization.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// One grid cell: a label + the closure that computes its result rows.
pub struct GridJob<R> {
    pub label: String,
    pub run: Box<dyn FnOnce() -> Result<R> + Send>,
}

impl<R> GridJob<R> {
    pub fn new(label: impl Into<String>, run: impl FnOnce() -> Result<R> + Send + 'static) -> Self {
        GridJob { label: label.into(), run: Box::new(run) }
    }
}

/// Run jobs on `workers` threads; results keep submission order.
/// Failures are reported per-cell and do not sink the whole grid.
pub fn run_grid<R: Send + 'static>(
    jobs: Vec<GridJob<R>>,
    workers: usize,
) -> Vec<(String, Result<R>)> {
    let n = jobs.len();
    let jobs: Vec<Mutex<Option<GridJob<R>>>> =
        jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<(String, Result<R>)>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = workers.max(1).min(n.max(1));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                let label = job.label.clone();
                eprintln!("[grid] {label} ...");
                let t0 = std::time::Instant::now();
                let res = (job.run)();
                eprintln!(
                    "[grid] {label} done in {:.1}s{}",
                    t0.elapsed().as_secs_f32(),
                    if res.is_err() { " (FAILED)" } else { "" }
                );
                *results[i].lock().unwrap() = Some((label, res));
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job completed"))
        .collect()
}

/// Default worker count: physical parallelism minus one for the PJRT queue
/// (via the crate-wide cached helper in `runtime::pool`).
pub fn default_workers() -> usize {
    crate::runtime::pool::parallelism().saturating_sub(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_preserves_order_and_collects_errors() {
        let jobs: Vec<GridJob<usize>> = (0..10)
            .map(|i| {
                GridJob::new(format!("job{i}"), move || {
                    if i == 3 {
                        anyhow::bail!("planned failure")
                    }
                    Ok(i * i)
                })
            })
            .collect();
        let results = run_grid(jobs, 4);
        assert_eq!(results.len(), 10);
        for (i, (label, res)) in results.iter().enumerate() {
            assert_eq!(label, &format!("job{i}"));
            if i == 3 {
                assert!(res.is_err());
            } else {
                assert_eq!(*res.as_ref().unwrap(), i * i);
            }
        }
    }

    #[test]
    fn grid_runs_with_more_workers_than_jobs() {
        let jobs = vec![GridJob::new("only", || Ok(42))];
        let results = run_grid(jobs, 16);
        assert_eq!(*results[0].1.as_ref().unwrap(), 42);
    }
}
