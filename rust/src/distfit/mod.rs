//! Distribution fitting: Student-t maximum likelihood (profile over nu),
//! normal fits, Kolmogorov-Smirnov distances and Q-Q extraction.
//!
//! This reproduces the paper's profiling methodology (Section 3.2, Tables
//! 1/11/12, Figure 2): fit both distributions to a weight/activation tensor,
//! report the fitted degrees of freedom and the KS-distance difference
//! `KS_normal - KS_t` (positive => the t-distribution fits better).

use crate::special::{normal, student_t};

/// A fitted location-scale Student-t.
#[derive(Clone, Copy, Debug)]
pub struct TFit {
    pub mu: f64,
    pub sigma: f64,
    pub nu: f64,
    pub loglik: f64,
}

/// A fitted normal.
#[derive(Clone, Copy, Debug)]
pub struct NormalFit {
    pub mu: f64,
    pub sigma: f64,
}

/// Full profiling result for one tensor (one row of Table 1/11).
#[derive(Clone, Copy, Debug)]
pub struct ProfileResult {
    pub t: TFit,
    pub normal: NormalFit,
    pub ks_t: f64,
    pub ks_normal: f64,
}

impl ProfileResult {
    /// KS-Delta of the paper: positive means the t-distribution is closer.
    pub fn ks_delta(&self) -> f64 {
        self.ks_normal - self.ks_t
    }
}

/// Normal MLE.
pub fn fit_normal(xs: &[f64]) -> NormalFit {
    let n = xs.len() as f64;
    let mu = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / n;
    NormalFit { mu, sigma: var.sqrt().max(1e-12) }
}

/// Scale MLE for fixed (mu, nu) via the standard EM weights iteration.
fn t_scale_mle(xs: &[f64], mu: f64, nu: f64, init: f64) -> f64 {
    let mut s2 = init * init;
    for _ in 0..50 {
        let mut acc = 0.0;
        for &x in xs {
            let d2 = (x - mu).powi(2);
            let w = (nu + 1.0) / (nu + d2 / s2);
            acc += w * d2;
        }
        let next = acc / xs.len() as f64;
        if (next - s2).abs() < 1e-12 * s2.max(1e-300) {
            s2 = next;
            break;
        }
        s2 = next;
    }
    s2.sqrt().max(1e-12)
}

fn t_loglik(xs: &[f64], mu: f64, sigma: f64, nu: f64) -> f64 {
    let ln_sigma = sigma.ln();
    xs.iter()
        .map(|&x| student_t::ln_pdf((x - mu) / sigma, nu) - ln_sigma)
        .sum()
}

/// Student-t MLE: golden-section search over ln(nu) on the profile
/// likelihood (scale re-estimated by EM at each candidate nu).
pub fn fit_student_t(xs: &[f64]) -> TFit {
    let nf = fit_normal(xs);
    let mu = nf.mu;
    let profile = |ln_nu: f64| -> (f64, f64) {
        let nu = ln_nu.exp();
        let sigma = t_scale_mle(xs, mu, nu, nf.sigma);
        (t_loglik(xs, mu, sigma, nu), sigma)
    };
    // golden-section maximize over ln nu in [ln 0.6, ln 150]
    let phi = (5.0f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut b) = (0.6f64.ln(), 150.0f64.ln());
    let mut c = b - phi * (b - a);
    let mut d = a + phi * (b - a);
    let (mut fc, _) = profile(c);
    let (mut fd, _) = profile(d);
    for _ in 0..40 {
        if fc > fd {
            b = d;
            d = c;
            fd = fc;
            c = b - phi * (b - a);
            fc = profile(c).0;
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + phi * (b - a);
            fd = profile(d).0;
        }
        if (b - a).abs() < 1e-4 {
            break;
        }
    }
    let ln_nu = 0.5 * (a + b);
    let nu = ln_nu.exp();
    let (ll, sigma) = profile(ln_nu);
    TFit { mu, sigma, nu, loglik: ll }
}

/// Two-sided KS distance between sorted samples and a CDF.
pub fn ks_distance(sorted: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let n = sorted.len() as f64;
    let mut d = 0.0f64;
    for (i, &x) in sorted.iter().enumerate() {
        let f = cdf(x);
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        d = d.max((f - lo).abs()).max((hi - f).abs());
    }
    d
}

/// Deterministic stride subsample to at most `cap` values (profiling only
/// needs shape, and the paper likewise downsamples huge tensors).
pub fn subsample(xs: &[f32], cap: usize) -> Vec<f64> {
    if xs.len() <= cap {
        return xs.iter().map(|&v| v as f64).collect();
    }
    let stride = xs.len() as f64 / cap as f64;
    (0..cap).map(|i| xs[(i as f64 * stride) as usize] as f64).collect()
}

/// Profile one tensor: fit t + normal, compute both KS distances.
pub fn profile_tensor(values: &[f32]) -> ProfileResult {
    let mut xs = subsample(values, 4096);
    let t = fit_student_t(&xs);
    let nfit = fit_normal(&xs);
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ks_t = ks_distance(&xs, |x| student_t::cdf((x - t.mu) / t.sigma, t.nu));
    let ks_n = ks_distance(&xs, |x| normal::cdf((x - nfit.mu) / nfit.sigma));
    ProfileResult { t, normal: nfit, ks_t, ks_normal: ks_n }
}

/// Q-Q data (Figure 2, right): theoretical quantiles of the fitted t and
/// normal against the empirical quantiles.
pub struct QqData {
    pub probs: Vec<f64>,
    pub empirical: Vec<f64>,
    pub theo_t: Vec<f64>,
    pub theo_normal: Vec<f64>,
}

pub fn qq_data(values: &[f32], n_points: usize) -> QqData {
    let mut xs = subsample(values, 8192);
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pr = profile_tensor(values);
    let mut probs = Vec::with_capacity(n_points);
    let mut empirical = Vec::with_capacity(n_points);
    let mut theo_t = Vec::with_capacity(n_points);
    let mut theo_normal = Vec::with_capacity(n_points);
    for i in 0..n_points {
        let p = (i as f64 + 0.5) / n_points as f64;
        let idx = ((p * xs.len() as f64) as usize).min(xs.len() - 1);
        probs.push(p);
        empirical.push(xs[idx]);
        theo_t.push(pr.t.mu + pr.t.sigma * student_t::ppf(p, pr.t.nu));
        theo_normal.push(pr.normal.mu + pr.normal.sigma * normal::ppf(p));
    }
    QqData { probs, empirical, theo_t, theo_normal }
}

/// Equal-width histogram (Figure 2, left), normalized to a density.
pub fn histogram(values: &[f32], bins: usize, lo: f64, hi: f64) -> Vec<(f64, f64)> {
    let mut counts = vec![0usize; bins];
    let mut total = 0usize;
    for &v in values {
        let v = v as f64;
        if v < lo || v >= hi {
            continue;
        }
        counts[((v - lo) / (hi - lo) * bins as f64) as usize] += 1;
        total += 1;
    }
    let w = (hi - lo) / bins as f64;
    counts
        .iter()
        .enumerate()
        .map(|(i, &c)| (lo + (i as f64 + 0.5) * w, c as f64 / (total as f64 * w)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn recovers_planted_nu() {
        let mut rng = Pcg64::new(1);
        for nu_true in [3.0, 5.0, 8.0] {
            let xs: Vec<f32> = rng.student_t_vec(20_000, nu_true, 0.02);
            let fit = fit_student_t(&subsample(&xs, 20_000));
            assert!(
                (fit.nu - nu_true).abs() < nu_true * 0.35,
                "planted {nu_true}, recovered {}",
                fit.nu
            );
            assert!((fit.sigma - 0.02).abs() < 0.004, "{}", fit.sigma);
        }
    }

    #[test]
    fn normal_data_fits_high_nu() {
        let mut rng = Pcg64::new(2);
        let xs: Vec<f32> = rng.normal_vec(20_000, 1.0);
        let fit = fit_student_t(&subsample(&xs, 20_000));
        assert!(fit.nu > 20.0, "normal data should fit high nu, got {}", fit.nu);
    }

    #[test]
    fn ks_delta_positive_for_t_data() {
        let mut rng = Pcg64::new(3);
        let xs: Vec<f32> = rng.student_t_vec(10_000, 4.0, 1.0);
        let pr = profile_tensor(&xs);
        assert!(pr.ks_delta() > 0.0, "{:?}", pr);
        assert!(pr.ks_t < 0.03, "t fit should be tight: {}", pr.ks_t);
    }

    #[test]
    fn ks_delta_near_zero_for_normal_data() {
        let mut rng = Pcg64::new(4);
        let xs: Vec<f32> = rng.normal_vec(10_000, 0.5);
        let pr = profile_tensor(&xs);
        assert!(pr.ks_delta().abs() < 0.02, "{}", pr.ks_delta());
        assert!(pr.ks_normal < 0.03);
    }

    #[test]
    fn ks_distance_uniform_sanity() {
        // empirical uniform sample vs its own CDF -> small distance
        let n = 1000;
        let xs: Vec<f64> = (0..n).map(|i| (i as f64 + 0.5) / n as f64).collect();
        let d = ks_distance(&xs, |x| x.clamp(0.0, 1.0));
        assert!(d < 0.01, "{d}");
        // against a wrong CDF -> large
        let d2 = ks_distance(&xs, |x| (x * x).clamp(0.0, 1.0));
        assert!(d2 > 0.2);
    }

    #[test]
    fn qq_straight_line_for_matching_dist() {
        let mut rng = Pcg64::new(5);
        let xs: Vec<f32> = rng.student_t_vec(20_000, 5.0, 1.0);
        let qq = qq_data(&xs, 64);
        // center-region points should track the fitted-t line closely
        for i in 8..56 {
            let d = (qq.empirical[i] - qq.theo_t[i]).abs();
            assert!(d < 0.15, "i={i} emp={} theo={}", qq.empirical[i], qq.theo_t[i]);
        }
    }

    #[test]
    fn histogram_integrates_to_one() {
        let mut rng = Pcg64::new(6);
        let xs: Vec<f32> = rng.normal_vec(50_000, 1.0);
        let h = histogram(&xs, 50, -4.0, 4.0);
        let w = 8.0 / 50.0;
        let total: f64 = h.iter().map(|(_, d)| d * w).sum();
        assert!((total - 1.0).abs() < 0.02, "{total}");
    }

    #[test]
    fn subsample_caps_length() {
        let xs = vec![1.0f32; 100_000];
        assert_eq!(subsample(&xs, 4096).len(), 4096);
        let ys = vec![1.0f32; 10];
        assert_eq!(subsample(&ys, 4096).len(), 10);
    }
}
