//! Pure-Rust reference forward passes, mirroring `python/compile/model.py`
//! op-for-op. Two jobs:
//!
//! 1. **Calibration**: GPTQ needs the inputs of every quantized linear and
//!    SmoothQuant needs per-channel activation maxima; `forward_lm` with an
//!    [`ActivationCapture`] records them without touching the XLA path.
//! 2. **Cross-validation**: integration tests compare these logits against
//!    the AOT `lm_fwd_fp32_*` executables to certify that the Rust view of
//!    the model matches what actually runs on the request path.

use std::collections::HashMap;

use anyhow::Result;

use crate::model_io::{Checkpoint, LinearBackend, ModelConfig};
use crate::tensor::Tensor;

/// Records the input activations `[rows, K]` of each named linear.
#[derive(Default, Debug)]
pub struct ActivationCapture {
    pub acts: HashMap<String, Vec<Tensor>>,
    /// Cap on captured rows per linear (memory guard).
    pub max_rows: usize,
}

impl ActivationCapture {
    pub fn new(max_rows: usize) -> Self {
        ActivationCapture { acts: HashMap::new(), max_rows }
    }

    fn push(&mut self, name: &str, x: &Tensor) {
        let cur: usize =
            self.acts.get(name).map(|v| v.iter().map(|t| t.rows()).sum()).unwrap_or(0);
        if cur >= self.max_rows {
            return;
        }
        self.acts.entry(name.to_string()).or_default().push(x.clone());
    }

    /// All captured rows for one linear, stacked `[M, K]`.
    pub fn stacked(&self, name: &str) -> Option<Tensor> {
        let parts = self.acts.get(name)?;
        let k = parts[0].cols();
        let m: usize = parts.iter().map(|t| t.rows()).sum();
        let mut data = Vec::with_capacity(m * k);
        for t in parts {
            data.extend_from_slice(t.data());
        }
        Some(Tensor::new(&[m, k], data))
    }
}

fn layernorm(x: &Tensor, g: &Tensor, b: &Tensor) -> Tensor {
    let (rows, d) = (x.rows(), x.cols());
    let mut out = vec![0.0f32; rows * d];
    for i in 0..rows {
        let row = x.row(i);
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for j in 0..d {
            out[i * d + j] = (row[j] - mu) * inv * g.data()[j] + b.data()[j];
        }
    }
    Tensor::new(&[rows, d], out)
}

/// tanh-approximate GELU, matching `jax.nn.gelu(approximate=True)`.
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// One named linear through the checkpoint's backend for that weight:
/// dense f32 matmul (fp32 or fake-quant dequantized tensors), or the fused
/// packed-4-bit `quant::lut_gemm` that expands nibble codes through the
/// format's 16-entry LUT inside the matmul — the serving path's ~8x
/// weight-traffic saving. Both run the same blocked `tensor::gemm` kernel
/// with identical K-block boundaries, so switching backend never changes
/// the batch-row bit-identity contract of the fused decode step.
/// W4A4 (`PackedW4a4`) goes further: the activation tile is itself encoded
/// to 4-bit codes on the fly (absmax blocks matching the weight's K-blocks)
/// and the product runs code x code through a 16x16 product LUT. That path
/// quantizes activations, so it trades the bit-identity contract for an
/// NLL-delta gate (see `rust/tests/simd_kernels.rs`).
pub fn apply_linear(p: &Checkpoint, x: &Tensor, name: &str) -> Result<Tensor> {
    match p.backend(name) {
        LinearBackend::Packed4 => Ok(crate::quant::lut_gemm(x, p.get_packed(name)?)),
        LinearBackend::PackedW4a4 => {
            let w = p.get_packed(name)?;
            let aq = p.act_quant().ok_or_else(|| {
                anyhow::anyhow!("backend says PackedW4a4 but no activation quantizer is installed")
            })?;
            let xq = aq.encode(x, w.block);
            Ok(crate::quant::w4a4_gemm(&xq, w))
        }
        LinearBackend::Dense => Ok(x.matmul(p.get(name)?)),
    }
}

/// Forward through one quantized-in-spirit linear, recording calibration
/// activations when asked (backend dispatch via [`apply_linear`]).
fn linear(
    p: &Checkpoint,
    x: &Tensor,
    name: &str,
    cap: &mut Option<&mut ActivationCapture>,
) -> Result<Tensor> {
    if let Some(c) = cap.as_deref_mut() {
        c.push(name, x);
    }
    apply_linear(p, x, name)
}

/// Causal self-attention for one layer over `x [S, D]` (single sequence).
fn attention(
    cfg: &ModelConfig,
    p: &Checkpoint,
    x: &Tensor,
    layer: usize,
    cap: &mut Option<&mut ActivationCapture>,
) -> Result<Tensor> {
    let (s, d) = (x.rows(), x.cols());
    let (h, dh) = (cfg.n_heads, cfg.d_head());
    let q = linear(p, x, &format!("l{layer}.wq"), cap)?;
    let k = linear(p, x, &format!("l{layer}.wk"), cap)?;
    let v = linear(p, x, &format!("l{layer}.wv"), cap)?;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Tensor::zeros(&[s, d]);
    let mut att_row = vec![0.0f32; s];
    for head in 0..h {
        let off = head * dh;
        for i in 0..s {
            // scores over keys 0..=i (causal); shares the per-head kernel
            // with the incremental step, so full and cached forwards stay
            // loop-order identical
            crate::tensor::attend_head(
                &q.row(i)[off..off + dh],
                k.data(),
                v.data(),
                d,
                off,
                i + 1,
                scale,
                &mut att_row,
                &mut ctx.row_mut(i)[off..off + dh],
            );
        }
    }
    linear(p, &ctx, &format!("l{layer}.wo"), cap)
}

/// Full LM forward: `tokens [S]` -> logits `[S, V]` for one sequence.
pub fn forward_lm(
    cfg: &ModelConfig,
    p: &Checkpoint,
    tokens: &[i32],
    mut cap: Option<&mut ActivationCapture>,
) -> Result<Tensor> {
    let s = tokens.len();
    assert!(s <= cfg.seq, "sequence too long: {s} > {}", cfg.seq);
    let d = cfg.d_model;
    let embed = p.get("embed")?;
    let pos = p.get("pos")?;
    let mut x = Tensor::zeros(&[s, d]);
    for (i, &t) in tokens.iter().enumerate() {
        let e = embed.row(t as usize);
        let pr = pos.row(i);
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = e[j] + pr[j];
        }
    }
    for l in 0..cfg.n_layers {
        let h = layernorm(&x, p.get(&format!("l{l}.ln1_g"))?, p.get(&format!("l{l}.ln1_b"))?);
        let a = attention(cfg, p, &h, l, &mut cap)?;
        x = x.add(&a);
        let h = layernorm(&x, p.get(&format!("l{l}.ln2_g"))?, p.get(&format!("l{l}.ln2_b"))?);
        let mut h = linear(p, &h, &format!("l{l}.w1"), &mut cap)?;
        h.map_inplace(gelu);
        let h = linear(p, &h, &format!("l{l}.w2"), &mut cap)?;
        x = x.add(&h);
    }
    let x = layernorm(&x, p.get("lnf_g")?, p.get("lnf_b")?);
    apply_linear(p, &x, "head")
}

// ---------------------------------------------------------------------------
// Incremental decode (KV cache)
// ---------------------------------------------------------------------------

/// One layer's borrowed K/V lanes, in whatever numeric format *and
/// layout* the store keeps them. The forwards dispatch attention on this:
/// fp32 lanes run the dense [`crate::tensor::attend_head`] loops
/// (bit-identical to the pre-packed-KV engine), packed lanes run the fused
/// dequant kernels ([`crate::tensor::lut_attend`]) which expand
/// `lut[code] * scale` inline — bit-identical to dequantizing the lanes
/// first. The `Paged*` variants carry a *block table* — position `j` lives
/// at row `j % page_rows` of page `j / page_rows` — and run the
/// page-walking kernels, which visit positions in the identical order (and
/// bits) as the contiguous ones.
#[derive(Clone)]
pub enum KvLanes<'a> {
    /// Dense lanes: `[capacity, d_model]` row-major by position, K and V.
    F32 { k: &'a [f32], v: &'a [f32] },
    /// Packed 4-bit lanes (nibble codes + per-block scales + LUT).
    Packed4 { k: crate::tensor::PackedLane<'a>, v: crate::tensor::PackedLane<'a> },
    /// Dense lanes split across fixed-size pages: entry `p` holds
    /// `[page_rows, d_model]` values (the last page may be partial).
    PagedF32 { k: Vec<&'a [f32]>, v: Vec<&'a [f32]>, page_rows: usize },
    /// Packed 4-bit lanes split across fixed-size pages (per page:
    /// `[page_rows, d/2]` codes + `[page_rows, d/block]` scales).
    PagedPacked4 {
        k_codes: Vec<&'a [u8]>,
        k_scales: Vec<&'a [f32]>,
        v_codes: Vec<&'a [u8]>,
        v_scales: Vec<&'a [f32]>,
        lut: &'a [f32; 16],
        d: usize,
        block: usize,
        page_rows: usize,
    },
}

/// Backing store for one sequence's per-layer keys/values during incremental
/// decode. `len()` positions are committed; [`forward_lm_step`] appends the
/// next position's K/V rows via [`KvStore::append_kv`] (which quantizing
/// stores encode on the way in), attends over [`KvStore::lanes`], and then
/// calls `advance` exactly once.
///
/// Implementations: [`SeqKvCache`] (one owned sequence, fp32 or packed
/// 4-bit) and the slot-pool views in `crate::serving::kv_cache` (many
/// sequences sharing preallocated storage, either format).
pub trait KvStore {
    /// Committed positions (the next token is written at this index).
    fn len(&self) -> usize;
    /// Maximum positions this store can hold.
    fn capacity(&self) -> usize;
    /// Write this position's K and V rows (length `d_model`) for `layer`
    /// at index `len()`. Packed stores quantize here.
    fn append_kv(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]);
    /// Borrow one layer's lanes for attention over positions `0..=len()`
    /// (the row just appended included).
    fn lanes(&self, layer: usize) -> KvLanes<'_>;
    /// Commit the position written at index `len()` (`len += 1`).
    fn advance(&mut self);
}

/// Owned single-sequence KV store (tests + standalone greedy decoding):
/// dense fp32 lanes by default, packed 4-bit lanes via
/// [`SeqKvCache::packed`].
pub struct SeqKvCache {
    store: SeqStore,
    len: usize,
    capacity: usize,
    d: usize,
}

enum SeqStore {
    F32 {
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
    Packed4 {
        fmt: crate::quant::KvFormat,
        k_codes: Vec<Vec<u8>>,
        k_scales: Vec<Vec<f32>>,
        v_codes: Vec<Vec<u8>>,
        v_scales: Vec<Vec<f32>>,
    },
    /// Paged fp32: `[layer][page]` buffers of `page_rows * d` values,
    /// allocated on demand as the sequence grows — the owned-sequence
    /// mirror of the serving engine's paged slot pool.
    PagedF32 {
        page_rows: usize,
        k: Vec<Vec<Vec<f32>>>,
        v: Vec<Vec<Vec<f32>>>,
    },
    /// Paged packed 4-bit: per layer, per page, codes + scales buffers.
    PagedPacked4 {
        fmt: crate::quant::KvFormat,
        page_rows: usize,
        k_codes: Vec<Vec<Vec<u8>>>,
        k_scales: Vec<Vec<Vec<f32>>>,
        v_codes: Vec<Vec<Vec<u8>>>,
        v_scales: Vec<Vec<Vec<f32>>>,
    },
}

impl SeqKvCache {
    pub fn new(cfg: &ModelConfig) -> SeqKvCache {
        SeqKvCache::with_capacity(cfg.n_layers, cfg.d_model, cfg.seq)
    }

    pub fn with_capacity(n_layers: usize, d_model: usize, capacity: usize) -> SeqKvCache {
        SeqKvCache {
            store: SeqStore::F32 {
                k: (0..n_layers).map(|_| vec![0.0; capacity * d_model]).collect(),
                v: (0..n_layers).map(|_| vec![0.0; capacity * d_model]).collect(),
            },
            len: 0,
            capacity,
            d: d_model,
        }
    }

    /// Packed 4-bit cache for a zoo model (`block = d_head`, the engine's
    /// geometry).
    pub fn packed(cfg: &ModelConfig, spec: &crate::formats::FormatSpec) -> SeqKvCache {
        SeqKvCache::packed_with_capacity(
            cfg.n_layers,
            cfg.d_model,
            cfg.seq,
            crate::quant::KvFormat::for_model(spec, cfg),
        )
    }

    pub fn packed_with_capacity(
        n_layers: usize,
        d_model: usize,
        capacity: usize,
        fmt: crate::quant::KvFormat,
    ) -> SeqKvCache {
        assert_eq!(d_model % fmt.block, 0, "block {} does not divide d {d_model}", fmt.block);
        let cb = capacity * fmt.codes_per_row(d_model);
        let sb = capacity * fmt.scales_per_row(d_model);
        SeqKvCache {
            store: SeqStore::Packed4 {
                k_codes: (0..n_layers).map(|_| vec![0u8; cb]).collect(),
                k_scales: (0..n_layers).map(|_| vec![0.0f32; sb]).collect(),
                v_codes: (0..n_layers).map(|_| vec![0u8; cb]).collect(),
                v_scales: (0..n_layers).map(|_| vec![0.0f32; sb]).collect(),
                fmt,
            },
            len: 0,
            capacity,
            d: d_model,
        }
    }

    /// Paged fp32 cache for a zoo model: positions live in on-demand
    /// `page_rows`-position pages instead of one contiguous lane. Lanes
    /// come back as [`KvLanes::PagedF32`], driving the page-walking
    /// attention kernels — bit-identical to the contiguous store.
    pub fn paged(cfg: &ModelConfig, page_rows: usize) -> SeqKvCache {
        SeqKvCache::paged_with_capacity(cfg.n_layers, cfg.d_model, cfg.seq, page_rows)
    }

    pub fn paged_with_capacity(
        n_layers: usize,
        d_model: usize,
        capacity: usize,
        page_rows: usize,
    ) -> SeqKvCache {
        assert!(page_rows > 0, "degenerate page size");
        SeqKvCache {
            store: SeqStore::PagedF32 {
                page_rows,
                k: (0..n_layers).map(|_| Vec::new()).collect(),
                v: (0..n_layers).map(|_| Vec::new()).collect(),
            },
            len: 0,
            capacity,
            d: d_model,
        }
    }

    /// Paged packed 4-bit cache (`block = d_head`): page-granular code and
    /// scale storage, attended through the paged fused dequant kernels.
    pub fn paged_packed(
        cfg: &ModelConfig,
        spec: &crate::formats::FormatSpec,
        page_rows: usize,
    ) -> SeqKvCache {
        let fmt = crate::quant::KvFormat::for_model(spec, cfg);
        assert!(page_rows > 0, "degenerate page size");
        assert_eq!(cfg.d_model % fmt.block, 0, "block {} does not divide d {}", fmt.block, cfg.d_model);
        SeqKvCache {
            store: SeqStore::PagedPacked4 {
                fmt,
                page_rows,
                k_codes: (0..cfg.n_layers).map(|_| Vec::new()).collect(),
                k_scales: (0..cfg.n_layers).map(|_| Vec::new()).collect(),
                v_codes: (0..cfg.n_layers).map(|_| Vec::new()).collect(),
                v_scales: (0..cfg.n_layers).map(|_| Vec::new()).collect(),
            },
            len: 0,
            capacity: cfg.seq,
            d: cfg.d_model,
        }
    }

    /// Forget all committed positions (buffers are overwritten on reuse).
    pub fn reset(&mut self) {
        self.len = 0;
    }
}

/// Grow a per-layer page list so `page` exists, zero-filled at `elems`
/// elements per page.
fn ensure_page<T: Clone + Default>(pages: &mut Vec<Vec<T>>, page: usize, elems: usize) {
    while pages.len() <= page {
        pages.push(vec![T::default(); elems]);
    }
}

impl KvStore for SeqKvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn append_kv(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let (pos, d) = (self.len, self.d);
        debug_assert!(pos < self.capacity, "append past capacity");
        assert_eq!(k_row.len(), d);
        assert_eq!(v_row.len(), d);
        match &mut self.store {
            SeqStore::F32 { k, v } => {
                k[layer][pos * d..(pos + 1) * d].copy_from_slice(k_row);
                v[layer][pos * d..(pos + 1) * d].copy_from_slice(v_row);
            }
            SeqStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let (cb, sb) = (fmt.codes_per_row(d), fmt.scales_per_row(d));
                fmt.encode_row(
                    k_row,
                    &mut k_codes[layer][pos * cb..(pos + 1) * cb],
                    &mut k_scales[layer][pos * sb..(pos + 1) * sb],
                );
                fmt.encode_row(
                    v_row,
                    &mut v_codes[layer][pos * cb..(pos + 1) * cb],
                    &mut v_scales[layer][pos * sb..(pos + 1) * sb],
                );
            }
            SeqStore::PagedF32 { page_rows, k, v } => {
                let (page, r) = (pos / *page_rows, pos % *page_rows);
                ensure_page(&mut k[layer], page, *page_rows * d);
                ensure_page(&mut v[layer], page, *page_rows * d);
                k[layer][page][r * d..(r + 1) * d].copy_from_slice(k_row);
                v[layer][page][r * d..(r + 1) * d].copy_from_slice(v_row);
            }
            SeqStore::PagedPacked4 { fmt, page_rows, k_codes, k_scales, v_codes, v_scales } => {
                let (cb, sb) = (fmt.codes_per_row(d), fmt.scales_per_row(d));
                let (page, r) = (pos / *page_rows, pos % *page_rows);
                ensure_page(&mut k_codes[layer], page, *page_rows * cb);
                ensure_page(&mut k_scales[layer], page, *page_rows * sb);
                ensure_page(&mut v_codes[layer], page, *page_rows * cb);
                ensure_page(&mut v_scales[layer], page, *page_rows * sb);
                fmt.encode_row(
                    k_row,
                    &mut k_codes[layer][page][r * cb..(r + 1) * cb],
                    &mut k_scales[layer][page][r * sb..(r + 1) * sb],
                );
                fmt.encode_row(
                    v_row,
                    &mut v_codes[layer][page][r * cb..(r + 1) * cb],
                    &mut v_scales[layer][page][r * sb..(r + 1) * sb],
                );
            }
        }
    }

    fn lanes(&self, layer: usize) -> KvLanes<'_> {
        match &self.store {
            SeqStore::F32 { k, v } => KvLanes::F32 { k: &k[layer], v: &v[layer] },
            SeqStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                KvLanes::Packed4 {
                    k: fmt.lane(&k_codes[layer], &k_scales[layer], self.d),
                    v: fmt.lane(&v_codes[layer], &v_scales[layer], self.d),
                }
            }
            SeqStore::PagedF32 { page_rows, k, v } => KvLanes::PagedF32 {
                k: k[layer].iter().map(|p| p.as_slice()).collect(),
                v: v[layer].iter().map(|p| p.as_slice()).collect(),
                page_rows: *page_rows,
            },
            SeqStore::PagedPacked4 { fmt, page_rows, k_codes, k_scales, v_codes, v_scales } => {
                KvLanes::PagedPacked4 {
                    k_codes: k_codes[layer].iter().map(|p| p.as_slice()).collect(),
                    k_scales: k_scales[layer].iter().map(|p| p.as_slice()).collect(),
                    v_codes: v_codes[layer].iter().map(|p| p.as_slice()).collect(),
                    v_scales: v_scales[layer].iter().map(|p| p.as_slice()).collect(),
                    lut: &fmt.lut,
                    d: self.d,
                    block: fmt.block,
                    page_rows: *page_rows,
                }
            }
        }
    }

    fn advance(&mut self) {
        self.len += 1;
    }
}

/// One row's multi-head attention over a layer's lanes, accumulated into
/// `ctx_row` (`+=`). fp32 lanes run the dense [`crate::tensor::attend_head`]
/// loops per head — the exact arithmetic of the pre-packed-KV engine —
/// while packed lanes run the fused dequant kernels, bit-identical to
/// dequantize-then-attend. `rows` is `pos + 1` (history plus the row just
/// appended).
#[allow(clippy::too_many_arguments)]
fn attend_lanes(
    lanes: KvLanes<'_>,
    q_row: &[f32],
    heads: usize,
    dh: usize,
    d: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_row: &mut [f32],
) {
    match lanes {
        KvLanes::F32 { k, v } => {
            for head in 0..heads {
                let off = head * dh;
                crate::tensor::attend_head(
                    &q_row[off..off + dh],
                    k,
                    v,
                    d,
                    off,
                    rows,
                    scale,
                    att,
                    &mut ctx_row[off..off + dh],
                );
            }
        }
        KvLanes::Packed4 { k, v } => {
            crate::tensor::lut_attend(q_row, k, v, heads, rows, scale, att, ctx_row);
        }
        KvLanes::PagedF32 { k, v, page_rows } => {
            for head in 0..heads {
                let off = head * dh;
                crate::tensor::attend_head_paged(
                    &q_row[off..off + dh],
                    &k,
                    &v,
                    page_rows,
                    d,
                    off,
                    rows,
                    scale,
                    att,
                    &mut ctx_row[off..off + dh],
                );
            }
        }
        KvLanes::PagedPacked4 {
            k_codes,
            k_scales,
            v_codes,
            v_scales,
            lut,
            d: lane_d,
            block,
            page_rows,
        } => {
            let k = crate::tensor::PagedPackedLane {
                pages_codes: &k_codes,
                pages_scales: &k_scales,
                lut,
                d: lane_d,
                block,
                page_rows,
            };
            let v = crate::tensor::PagedPackedLane {
                pages_codes: &v_codes,
                pages_scales: &v_scales,
                lut,
                d: lane_d,
                block,
                page_rows,
            };
            crate::tensor::lut_attend_paged(q_row, k, v, heads, rows, scale, att, ctx_row);
        }
    }
}

/// One incremental forward step: embed `token` at position `kv.len()`,
/// attend over all cached positions plus this one, append this position's
/// per-layer K/V rows to the store, and return the logits `[1, V]`.
///
/// Arithmetic (loop order included) matches [`forward_lm`] row-for-row, so
/// greedy decoding through this path is token-identical to re-forwarding the
/// full prefix each step — the `incremental_matches_full_forward` test below
/// certifies it. Works unchanged on fake-quant checkpoints from
/// `coordinator::pipeline::fake_quant_checkpoint` and on packed 4-bit
/// checkpoints from `packed_checkpoint` (every linear dispatches through
/// [`apply_linear`]), and on any KV lane format the store keeps — fp32
/// lanes reproduce today's bits exactly, packed 4-bit lanes are
/// bit-identical to a dequantize-then-attend oracle over the same codes
/// (`rust/tests/quant_kv.rs`).
pub fn forward_lm_step(
    cfg: &ModelConfig,
    p: &Checkpoint,
    token: i32,
    kv: &mut dyn KvStore,
) -> Result<Tensor> {
    let pos = kv.len();
    let d = cfg.d_model;
    anyhow::ensure!(pos < cfg.seq, "position {pos} out of range for seq {}", cfg.seq);
    anyhow::ensure!(pos < kv.capacity(), "kv store full at {pos}/{}", kv.capacity());
    let embed = p.get("embed")?;
    let posm = p.get("pos")?;
    let mut x = Tensor::zeros(&[1, d]);
    {
        let e = embed.row(token as usize);
        let pr = posm.row(pos);
        let row = x.row_mut(0);
        for j in 0..d {
            row[j] = e[j] + pr[j];
        }
    }
    let (heads, dh) = (cfg.n_heads, cfg.d_head());
    let scale = 1.0 / (dh as f32).sqrt();
    let mut att_row = vec![0.0f32; pos + 1];
    for l in 0..cfg.n_layers {
        let h = layernorm(&x, p.get(&format!("l{l}.ln1_g"))?, p.get(&format!("l{l}.ln1_b"))?);
        let q = apply_linear(p, &h, &format!("l{l}.wq"))?;
        let kx = apply_linear(p, &h, &format!("l{l}.wk"))?;
        let vx = apply_linear(p, &h, &format!("l{l}.wv"))?;
        kv.append_kv(l, kx.row(0), vx.row(0));
        let mut ctx = Tensor::zeros(&[1, d]);
        attend_lanes(
            kv.lanes(l),
            q.row(0),
            heads,
            dh,
            d,
            pos + 1,
            scale,
            &mut att_row,
            ctx.row_mut(0),
        );
        let a = apply_linear(p, &ctx, &format!("l{l}.wo"))?;
        x = x.add(&a);
        let h = layernorm(&x, p.get(&format!("l{l}.ln2_g"))?, p.get(&format!("l{l}.ln2_b"))?);
        let mut h = apply_linear(p, &h, &format!("l{l}.w1"))?;
        h.map_inplace(gelu);
        let h = apply_linear(p, &h, &format!("l{l}.w2"))?;
        x = x.add(&h);
    }
    kv.advance();
    let x = layernorm(&x, p.get("lnf_g")?, p.get("lnf_b")?);
    apply_linear(p, &x, "head")
}

/// GEMM launches one [`forward_lm_step_batch`] call issues: q/k/v/o/w1/w2
/// per layer plus the head projection. Lives next to the forward so the
/// engine's fused-GEMM metric cannot drift from the actual matmul count —
/// update both together when the forward's linear structure changes.
pub fn step_batch_gemms(cfg: &ModelConfig) -> u64 {
    6 * cfg.n_layers as u64 + 1
}

/// One fused decode step for a whole batch: embed `tokens[b]` at position
/// `kvs[b].len()`, run every linear as one `[B, d] x [d, N]` GEMM (instead
/// of `B` separate `[1, d]` matmuls), attend each row over its *own* KV
/// lane, append each row's per-layer K/V, and return the logits `[B, V]`
/// (row `b` belongs to `kvs[b]`).
///
/// Rows may sit at different positions (ragged batches: sessions join and
/// leave mid-flight). Because every matmul routes through the shared
/// [`crate::tensor::gemm`] kernel — whose per-row arithmetic is independent
/// of `B` — and the attention/layernorm loops mirror [`forward_lm_step`]
/// exactly, row `b` of the result is **bit-identical** to calling
/// `forward_lm_step(cfg, p, tokens[b], kvs[b])` on its own
/// (`rust/tests/batched_decode.rs` enforces this across fp32 and fake-quant
/// checkpoints). Either all rows commit (`advance`) or, on error, none do.
pub fn forward_lm_step_batch(
    cfg: &ModelConfig,
    p: &Checkpoint,
    tokens: &[i32],
    kvs: &mut [&mut dyn KvStore],
) -> Result<Tensor> {
    let b = tokens.len();
    anyhow::ensure!(b > 0, "empty batch");
    anyhow::ensure!(
        b == kvs.len(),
        "batch mismatch: {b} tokens for {} kv stores",
        kvs.len()
    );
    let d = cfg.d_model;
    let positions: Vec<usize> = kvs.iter().map(|kv| kv.len()).collect();
    for (row, &pos) in positions.iter().enumerate() {
        anyhow::ensure!(pos < cfg.seq, "row {row}: position {pos} out of range for seq {}", cfg.seq);
        anyhow::ensure!(
            pos < kvs[row].capacity(),
            "row {row}: kv store full at {pos}/{}",
            kvs[row].capacity()
        );
    }
    let embed = p.get("embed")?;
    let posm = p.get("pos")?;
    let mut x = Tensor::zeros(&[b, d]);
    for (row, &t) in tokens.iter().enumerate() {
        let e = embed.row(t as usize);
        let pr = posm.row(positions[row]);
        let xr = x.row_mut(row);
        for j in 0..d {
            xr[j] = e[j] + pr[j];
        }
    }
    let (heads, dh) = (cfg.n_heads, cfg.d_head());
    let scale = 1.0 / (dh as f32).sqrt();
    let mut att_row = vec![0.0f32; positions.iter().copied().max().unwrap() + 1];
    for l in 0..cfg.n_layers {
        let h = layernorm(&x, p.get(&format!("l{l}.ln1_g"))?, p.get(&format!("l{l}.ln1_b"))?);
        // fused projections: one [B, d] x [d, d] GEMM each, not B
        // (dense or packed-LUT, per the checkpoint's backend)
        let q = apply_linear(p, &h, &format!("l{l}.wq"))?;
        let kx = apply_linear(p, &h, &format!("l{l}.wk"))?;
        let vx = apply_linear(p, &h, &format!("l{l}.wv"))?;
        let mut ctx = Tensor::zeros(&[b, d]);
        for row in 0..b {
            let pos = positions[row];
            let kv = &mut *kvs[row];
            kv.append_kv(l, kx.row(row), vx.row(row));
            attend_lanes(
                kv.lanes(l),
                q.row(row),
                heads,
                dh,
                d,
                pos + 1,
                scale,
                &mut att_row,
                ctx.row_mut(row),
            );
        }
        let a = apply_linear(p, &ctx, &format!("l{l}.wo"))?;
        x = x.add(&a);
        let h = layernorm(&x, p.get(&format!("l{l}.ln2_g"))?, p.get(&format!("l{l}.ln2_b"))?);
        let mut h = apply_linear(p, &h, &format!("l{l}.w1"))?;
        h.map_inplace(gelu);
        let h = apply_linear(p, &h, &format!("l{l}.w2"))?;
        x = x.add(&h);
    }
    for kv in kvs.iter_mut() {
        kv.advance();
    }
    let x = layernorm(&x, p.get("lnf_g")?, p.get("lnf_b")?);
    apply_linear(p, &x, "head")
}

/// Greedy multi-token generation over the incremental path: prefill the
/// prompt token by token, then decode until `max_new` tokens, `eos`, or the
/// positional window runs out. Returns only the generated tokens.
pub fn generate_greedy(
    cfg: &ModelConfig,
    p: &Checkpoint,
    prompt: &[i32],
    max_new: usize,
    eos: Option<i32>,
) -> Result<Vec<i32>> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(prompt.len() <= cfg.seq, "prompt longer than seq {}", cfg.seq);
    let mut kv = SeqKvCache::new(cfg);
    let mut logits = Tensor::zeros(&[1, cfg.vocab]);
    for &t in prompt {
        logits = forward_lm_step(cfg, p, t, &mut kv)?;
    }
    let mut out = Vec::new();
    while out.len() < max_new {
        let next = crate::tensor::argmax(logits.row(0)) as i32;
        out.push(next);
        if Some(next) == eos || out.len() >= max_new || kv.len() >= cfg.seq {
            break;
        }
        logits = forward_lm_step(cfg, p, next, &mut kv)?;
    }
    Ok(out)
}

/// Mean next-token NLL of one sequence (`tokens [S+1]`).
pub fn lm_nll(cfg: &ModelConfig, p: &Checkpoint, tokens: &[i32]) -> Result<f64> {
    let s = tokens.len() - 1;
    let logits = forward_lm(cfg, p, &tokens[..s], None)?;
    let logp = logits.log_softmax_last();
    let mut total = 0.0f64;
    for i in 0..s {
        total -= logp.at2(i, tokens[i + 1] as usize) as f64;
    }
    Ok(total / s as f64)
}

/// Run calibration: forward `n_seqs` sequences, capturing every quant-linear
/// input (used by GPTQ and SmoothQuant).
pub fn calibrate_lm(
    cfg: &ModelConfig,
    p: &Checkpoint,
    seqs: &[Vec<i32>],
    max_rows: usize,
) -> Result<ActivationCapture> {
    let mut cap = ActivationCapture::new(max_rows);
    for seq in seqs {
        forward_lm(cfg, p, seq, Some(&mut cap))?;
    }
    Ok(cap)
}

// ---------------------------------------------------------------------------
// Classifier forwards (vision roles, Table 9)
// ---------------------------------------------------------------------------

/// Classifier kind mirror of `model.py` CLS_ZOO.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClsKind {
    Mlp,
    Cnn,
}

/// Classifier config (image 16x16x1, 10 classes as in model.py).
#[derive(Clone, Copy, Debug)]
pub struct ClsConfig {
    pub name: &'static str,
    pub kind: ClsKind,
    pub image: usize,
    pub classes: usize,
    pub hidden: usize,
    pub channels: usize,
    pub batch_eval: usize,
    pub batch_train: usize,
    pub train_steps: usize,
}

pub const CLS_ZOO: [ClsConfig; 2] = [
    ClsConfig { name: "mlp", kind: ClsKind::Mlp, image: 16, classes: 10, hidden: 128, channels: 16, batch_eval: 64, batch_train: 64, train_steps: 400 },
    ClsConfig { name: "cnn", kind: ClsKind::Cnn, image: 16, classes: 10, hidden: 128, channels: 16, batch_eval: 64, batch_train: 64, train_steps: 400 },
];

pub fn cls_zoo(name: &str) -> Result<ClsConfig> {
    CLS_ZOO
        .iter()
        .copied()
        .find(|c| c.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown classifier `{name}`"))
}

impl ClsConfig {
    pub fn quant_linear_names(&self) -> Vec<String> {
        match self.kind {
            ClsKind::Mlp => vec!["fc1".into(), "fc2".into(), "fc3".into()],
            ClsKind::Cnn => vec!["conv1".into(), "conv2".into(), "fc".into()],
        }
    }

    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let n_in = self.image * self.image;
        match self.kind {
            ClsKind::Mlp => vec![
                ("fc1".into(), vec![n_in, self.hidden]),
                ("b1".into(), vec![self.hidden]),
                ("fc2".into(), vec![self.hidden, self.hidden]),
                ("b2".into(), vec![self.hidden]),
                ("fc3".into(), vec![self.hidden, self.classes]),
                ("b3".into(), vec![self.classes]),
            ],
            ClsKind::Cnn => vec![
                ("conv1".into(), vec![9, self.channels]),
                ("cb1".into(), vec![self.channels]),
                ("conv2".into(), vec![9 * self.channels, self.channels]),
                ("cb2".into(), vec![self.channels]),
                ("fc".into(), vec![self.channels, self.classes]),
                ("fcb".into(), vec![self.classes]),
            ],
        }
    }
}

/// im2col for 3x3/pad-1 convs, matching `model.py::_im2col`:
/// `x [B, side*side*chans] -> [B*side*side, 9*chans]`.
fn im2col(x: &Tensor, side: usize, chans: usize) -> Tensor {
    let b = x.rows();
    let mut out = vec![0.0f32; b * side * side * 9 * chans];
    let ow = 9 * chans;
    for bi in 0..b {
        let img = x.row(bi);
        for y in 0..side {
            for xx in 0..side {
                let orow = (bi * side * side + y * side + xx) * ow;
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        let sy = y as isize + dy as isize - 1;
                        let sx = xx as isize + dx as isize - 1;
                        if sy < 0 || sx < 0 || sy >= side as isize || sx >= side as isize {
                            continue;
                        }
                        let src = ((sy as usize) * side + sx as usize) * chans;
                        let dst = orow + (dy * 3 + dx) * chans;
                        out[dst..dst + chans]
                            .copy_from_slice(&img[src..src + chans]);
                    }
                }
            }
        }
    }
    Tensor::new(&[b * side * side, ow], out)
}

fn add_bias(x: &Tensor, b: &Tensor) -> Tensor {
    let (rows, n) = (x.rows(), x.cols());
    let mut out = x.clone();
    for i in 0..rows {
        let row = out.row_mut(i);
        for j in 0..n {
            row[j] += b.data()[j];
        }
    }
    out
}

/// Classifier forward: `x [B, image*image]` -> logits `[B, classes]`.
pub fn forward_cls(
    cfg: &ClsConfig,
    p: &Checkpoint,
    x: &Tensor,
    mut cap: Option<&mut ActivationCapture>,
) -> Result<Tensor> {
    match cfg.kind {
        ClsKind::Mlp => {
            let mut h = add_bias(&linear(p, x, "fc1", &mut cap)?, p.get("b1")?);
            h.map_inplace(gelu);
            let mut h = add_bias(&linear(p, &h, "fc2", &mut cap)?, p.get("b2")?);
            h.map_inplace(gelu);
            Ok(add_bias(&linear(p, &h, "fc3", &mut cap)?, p.get("b3")?))
        }
        ClsKind::Cnn => {
            let (b, side, c) = (x.rows(), cfg.image, cfg.channels);
            let h = im2col(x, side, 1);
            let mut h = add_bias(&linear(p, &h, "conv1", &mut cap)?, p.get("cb1")?);
            h.map_inplace(gelu);
            let h = im2col(&h.reshape(&[b, side * side * c]), side, c);
            let mut h = add_bias(&linear(p, &h, "conv2", &mut cap)?, p.get("cb2")?);
            h.map_inplace(gelu);
            // global average pool over the side*side positions
            let mut pooled = Tensor::zeros(&[b, c]);
            for bi in 0..b {
                for pos in 0..side * side {
                    let row = h.row(bi * side * side + pos);
                    let prow = pooled.row_mut(bi);
                    for j in 0..c {
                        prow[j] += row[j] / (side * side) as f32;
                    }
                }
            }
            Ok(add_bias(&linear(p, &pooled, "fc", &mut cap)?, p.get("fcb")?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_io::zoo;
    use crate::rng::Pcg64;

    fn random_ckpt(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = Pcg64::new(seed);
        let mut c = Checkpoint::new();
        for (name, shape) in cfg.param_specs() {
            let n: usize = shape.iter().product();
            let leaf = name.rsplit('.').next().unwrap();
            let t = if leaf.ends_with("_g") {
                Tensor::full(&shape, 1.0)
            } else if leaf.ends_with("_b") {
                Tensor::zeros(&shape)
            } else {
                let std = (2.0 / shape[0] as f64).sqrt();
                Tensor::new(&shape, rng.normal_vec(n, std))
            };
            c.insert(&name, t);
        }
        c
    }

    #[test]
    fn lm_forward_shapes_and_finite() {
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 1);
        let tokens: Vec<i32> = (0..cfg.seq as i32).map(|i| i % cfg.vocab as i32).collect();
        let logits = forward_lm(&cfg, &p, &tokens, None).unwrap();
        assert_eq!(logits.shape(), &[cfg.seq, cfg.vocab]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        // changing a future token must not affect earlier logits
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 2);
        let mut t1: Vec<i32> = (0..16).map(|i| (i * 3) % cfg.vocab as i32).collect();
        let l1 = forward_lm(&cfg, &p, &t1, None).unwrap();
        t1[15] = (t1[15] + 7) % cfg.vocab as i32;
        let l2 = forward_lm(&cfg, &p, &t1, None).unwrap();
        for i in 0..15 {
            for j in 0..cfg.vocab {
                assert!((l1.at2(i, j) - l2.at2(i, j)).abs() < 1e-5, "pos {i} leaked");
            }
        }
    }

    #[test]
    fn capture_collects_all_linears() {
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 3);
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|s| (0..16).map(|i| ((i + s * 5) % cfg.vocab) as i32).collect())
            .collect();
        let cap = calibrate_lm(&cfg, &p, &seqs, 4096).unwrap();
        for name in cfg.quant_linear_names() {
            let x = cap.stacked(&name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(x.rows(), 3 * 16, "{name}");
            let expected_k = if name.ends_with("w2") { cfg.d_ff } else { cfg.d_model };
            assert_eq!(x.cols(), expected_k, "{name}");
        }
    }

    #[test]
    fn capture_respects_row_cap() {
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 4);
        let seqs: Vec<Vec<i32>> =
            (0..8).map(|_| (0..32).map(|i| i % cfg.vocab as i32).collect()).collect();
        let cap = calibrate_lm(&cfg, &p, &seqs, 64).unwrap();
        for name in cfg.quant_linear_names() {
            let x = cap.stacked(&name).unwrap();
            assert!(x.rows() <= 96, "{}: {} rows", name, x.rows()); // cap + one seq overshoot
        }
    }

    #[test]
    fn incremental_matches_full_forward() {
        // every position's logits from the KV-cached step must match the
        // matching row of the full forward on the same prefix
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 6);
        let tokens: Vec<i32> = (0..16).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();
        let full = forward_lm(&cfg, &p, &tokens, None).unwrap();
        let mut kv = SeqKvCache::new(&cfg);
        for (i, &t) in tokens.iter().enumerate() {
            let step = forward_lm_step(&cfg, &p, t, &mut kv).unwrap();
            assert_eq!(kv.len(), i + 1);
            for j in 0..cfg.vocab {
                assert!(
                    (step.at2(0, j) - full.at2(i, j)).abs() < 1e-4,
                    "pos {i} vocab {j}: {} vs {}",
                    step.at2(0, j),
                    full.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn greedy_decoding_equivalence() {
        // incremental generation == generation by re-forwarding the growing
        // prefix through the full path (the decode-engine acceptance check)
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 7);
        let prompt: Vec<i32> = (0..8).map(|i| (i * 5 + 1) % cfg.vocab as i32).collect();
        let max_new = 12;
        let fast = generate_greedy(&cfg, &p, &prompt, max_new, None).unwrap();
        let mut slow = Vec::new();
        let mut ctxt = prompt.clone();
        for _ in 0..max_new {
            let logits = forward_lm(&cfg, &p, &ctxt, None).unwrap();
            let next = crate::tensor::argmax(logits.row(ctxt.len() - 1)) as i32;
            slow.push(next);
            ctxt.push(next);
        }
        assert_eq!(fast, slow);
    }

    #[test]
    fn generate_stops_on_eos_and_window() {
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 8);
        let prompt = [1i32, 2, 3];
        // whatever the first greedy token is, using it as EOS stops at 1
        let one = generate_greedy(&cfg, &p, &prompt, 8, None).unwrap();
        let eos = one[0];
        let stopped = generate_greedy(&cfg, &p, &prompt, 8, Some(eos)).unwrap();
        assert_eq!(stopped, vec![eos]);
        // the positional window bounds generation even with a huge budget
        let long = generate_greedy(&cfg, &p, &prompt, 10_000, None).unwrap();
        assert!(long.len() <= cfg.seq - prompt.len() + 1, "{}", long.len());
        // cache reuse after reset stays consistent
        let mut kv = SeqKvCache::new(&cfg);
        let a = forward_lm_step(&cfg, &p, 5, &mut kv).unwrap();
        kv.reset();
        let b = forward_lm_step(&cfg, &p, 5, &mut kv).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn batched_step_matches_single_steps_bitwise() {
        // ragged batch: three lanes at different positions, one fused call vs
        // three sequential forward_lm_step calls — rows must be bit-identical
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 10);
        let prompts: [&[i32]; 3] = [&[1, 2, 3, 4, 5], &[9, 8, 7], &[4]];
        // sequential reference, recording every step's logits per lane
        let mut expect: Vec<Vec<Tensor>> = Vec::new();
        for prompt in prompts {
            let mut kv = SeqKvCache::new(&cfg);
            expect.push(
                prompt
                    .iter()
                    .map(|&t| forward_lm_step(&cfg, &p, t, &mut kv).unwrap())
                    .collect(),
            );
        }
        // fused path: lanes advance in lockstep, dropping out as they run dry
        let mut kvs: Vec<SeqKvCache> = (0..3).map(|_| SeqKvCache::new(&cfg)).collect();
        for step in 0..prompts.iter().map(|pr| pr.len()).max().unwrap() {
            let live: Vec<usize> = (0..3).filter(|&i| step < prompts[i].len()).collect();
            let tokens: Vec<i32> = live.iter().map(|&i| prompts[i][step]).collect();
            let mut stores: Vec<&mut dyn KvStore> = kvs
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| step < prompts[*i].len())
                .map(|(_, kv)| kv as &mut dyn KvStore)
                .collect();
            let logits = forward_lm_step_batch(&cfg, &p, &tokens, &mut stores).unwrap();
            assert_eq!(logits.shape(), &[live.len(), cfg.vocab]);
            for (r, &lane) in live.iter().enumerate() {
                assert_eq!(
                    logits.row(r),
                    expect[lane][step].row(0),
                    "lane {lane} step {step}: fused row must be bit-identical"
                );
            }
        }
        for (lane, prompt) in prompts.iter().enumerate() {
            assert_eq!(kvs[lane].len(), prompt.len(), "lane {lane} committed its positions");
        }
    }

    #[test]
    fn batched_step_rejects_bad_batches() {
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 11);
        // empty batch
        let mut none: Vec<&mut dyn KvStore> = Vec::new();
        assert!(forward_lm_step_batch(&cfg, &p, &[], &mut none).is_err());
        // tokens / stores length mismatch
        let mut kv = SeqKvCache::new(&cfg);
        let mut one: Vec<&mut dyn KvStore> = vec![&mut kv];
        assert!(forward_lm_step_batch(&cfg, &p, &[1, 2], &mut one).is_err());
        // a full lane poisons the whole call and commits nothing
        let mut full = SeqKvCache::with_capacity(cfg.n_layers, cfg.d_model, 1);
        let mut open = SeqKvCache::new(&cfg);
        {
            let mut pair: Vec<&mut dyn KvStore> = vec![&mut full];
            forward_lm_step_batch(&cfg, &p, &[3], &mut pair).unwrap();
        }
        let mut pair: Vec<&mut dyn KvStore> = vec![&mut full, &mut open];
        assert!(forward_lm_step_batch(&cfg, &p, &[4, 5], &mut pair).is_err());
        assert_eq!(open.len(), 0, "no partial commits on batch failure");
    }

    #[test]
    fn step_rejects_overflow() {
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 9);
        let mut kv = SeqKvCache::with_capacity(cfg.n_layers, cfg.d_model, 2);
        assert!(forward_lm_step(&cfg, &p, 1, &mut kv).is_ok());
        assert!(forward_lm_step(&cfg, &p, 2, &mut kv).is_ok());
        // capacity 2 exhausted even though cfg.seq allows more
        assert!(forward_lm_step(&cfg, &p, 3, &mut kv).is_err());
    }

    #[test]
    fn packed_kv_cache_decodes_deterministically_and_resets() {
        // deep equivalence lives in tests/quant_kv.rs; this pins the owned
        // packed store's basic lifecycle (finite logits, reset reuse)
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 12);
        let spec = crate::formats::must("sf4");
        let mut kv = SeqKvCache::packed(&cfg, &spec);
        let a = forward_lm_step(&cfg, &p, 5, &mut kv).unwrap();
        assert_eq!(kv.len(), 1);
        assert!(a.data().iter().all(|v| v.is_finite()));
        let b = forward_lm_step(&cfg, &p, 7, &mut kv).unwrap();
        assert_eq!(kv.len(), 2);
        kv.reset();
        let a2 = forward_lm_step(&cfg, &p, 5, &mut kv).unwrap();
        assert_eq!(a.data(), a2.data(), "reset packed cache replays identically");
        let b2 = forward_lm_step(&cfg, &p, 7, &mut kv).unwrap();
        assert_eq!(b.data(), b2.data());
    }

    #[test]
    fn paged_seq_cache_is_bit_identical_to_contiguous() {
        // page boundaries (page_rows 4, 16 steps) must never change a bit:
        // the paged store drives the page-walking kernels over the same
        // values the contiguous store attends in one run
        let cfg = zoo("nano").unwrap();
        let p = random_ckpt(&cfg, 13);
        let tokens: Vec<i32> = (0..16).map(|i| (i * 11 + 2) % cfg.vocab as i32).collect();
        let mut flat = SeqKvCache::new(&cfg);
        let mut paged = SeqKvCache::paged(&cfg, 4);
        for (i, &t) in tokens.iter().enumerate() {
            let a = forward_lm_step(&cfg, &p, t, &mut flat).unwrap();
            let b = forward_lm_step(&cfg, &p, t, &mut paged).unwrap();
            assert_eq!(a.data(), b.data(), "step {i}: fp32 paging changed bits");
        }
        // packed lanes: paged codes/scales attend identically to contiguous
        let spec = crate::formats::must("sf4");
        let mut flat = SeqKvCache::packed(&cfg, &spec);
        let mut paged = SeqKvCache::paged_packed(&cfg, &spec, 4);
        for (i, &t) in tokens.iter().enumerate() {
            let a = forward_lm_step(&cfg, &p, t, &mut flat).unwrap();
            let b = forward_lm_step(&cfg, &p, t, &mut paged).unwrap();
            assert_eq!(a.data(), b.data(), "step {i}: packed paging changed bits");
        }
    }

    #[test]
    fn gelu_matches_reference_points() {
        // values from jax.nn.gelu(approximate=True)
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!((gelu(3.0) - 2.996_36).abs() < 1e-3);
    }

    #[test]
    fn cls_forward_shapes() {
        for cfg in CLS_ZOO {
            let mut rng = Pcg64::new(5);
            let mut p = Checkpoint::new();
            for (name, shape) in cfg.param_specs() {
                let n: usize = shape.iter().product();
                let t = if shape.len() == 1 {
                    Tensor::zeros(&shape)
                } else {
                    Tensor::new(&shape, rng.normal_vec(n, (2.0 / shape[0] as f64).sqrt()))
                };
                p.insert(&name, t);
            }
            let x = Tensor::new(&[4, 256], rng.normal_vec(4 * 256, 1.0));
            let logits = forward_cls(&cfg, &p, &x, None).unwrap();
            assert_eq!(logits.shape(), &[4, cfg.classes]);
            assert!(logits.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn im2col_center_pixel_identity() {
        // kernel position (1,1) of the patch must be the pixel itself
        let side = 4;
        let x = Tensor::from_fn(&[1, side * side], |i| i as f32);
        let pat = im2col(&x, side, 1);
        assert_eq!(pat.shape(), &[side * side, 9]);
        for pos in 0..side * side {
            assert_eq!(pat.at2(pos, 4), pos as f32);
        }
    }
}
