//! `repro` — CLI entrypoint. See `cli` module for command dispatch.
fn main() {
    if let Err(e) = llm_datatypes::cli::main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
