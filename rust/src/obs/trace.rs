//! Span/event tracing into per-thread ring buffers.
//!
//! Every instrumented site first checks one global flag ([`enabled`], a
//! relaxed atomic load) — with tracing off that load is the *entire* cost,
//! so span sites are safe inside per-token and per-kernel paths. Enabled,
//! records go into the recording thread's own bounded ring (drop-oldest,
//! with a dropped counter), so hot threads never contend with each other;
//! [`snapshot_and_drain`] collects every ring for export.
//!
//! Records target *tracks*: small integer lanes the Chrome exporter renders
//! as named rows. Each recording thread gets its own track on first use
//! (named after the thread — pool workers show up as `llmdt-pool-N`), and
//! logical timelines that outlive any one thread (decode sessions) get
//! stable named tracks via [`named_track`] / [`session_track`]. A record is
//! always *stored* in the recording thread's ring but may *target* another
//! track — the engine thread records a session's `queued` span onto that
//! session's track.

use std::cell::OnceCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::obs::clock;

/// Default per-thread ring capacity, in records.
pub const DEFAULT_RING_CAPACITY: usize = 16 * 1024;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);

/// All track names ever allocated; track id = index + 1 (0 is unused so
/// Chrome metadata rows sort after the process row).
static TRACKS: Mutex<Vec<String>> = Mutex::new(Vec::new());

/// Every thread ring ever registered (rings outlive their threads so a
/// drained snapshot still sees records from finished workers).
static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL_RING: OnceCell<(u32, Arc<Mutex<Ring>>)> = const { OnceCell::new() };
}

/// Is tracing on? One relaxed load — the whole disabled-path cost.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off. Enabling pins [`clock::epoch`] so all timestamps
/// share a reference.
pub fn set_enabled(on: bool) {
    if on {
        clock::epoch();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

/// Shrink/grow the per-thread ring capacity (takes effect on the next
/// push; existing overflow is trimmed then). Tests use tiny rings to pin
/// wraparound behaviour.
pub fn set_ring_capacity(records: usize) {
    RING_CAPACITY.store(records.max(1), Ordering::SeqCst);
}

/// How a record renders in the Chrome exporter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A duration slice (`ph:"X"` complete event).
    Complete,
    /// A point-in-time marker (`ph:"i"` instant event).
    Instant,
}

/// One traced event: `(name, t_start, t_end, args)` plus its target track.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub kind: EventKind,
    /// Category shown by Chrome's filter UI: "engine", "session",
    /// "kernel", "pool".
    pub cat: &'static str,
    pub name: &'static str,
    /// Track (Chrome `tid`) this record renders on.
    pub track: u32,
    /// Microseconds since [`clock::epoch`].
    pub ts_us: u64,
    /// Duration in microseconds (0 for instants).
    pub dur_us: u64,
    /// Numeric annotations (batch rows, page-pool pressure, queue wait…).
    pub args: Vec<(&'static str, f64)>,
}

struct Ring {
    buf: VecDeque<SpanRecord>,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) {
        let cap = RING_CAPACITY.load(Ordering::Relaxed).max(1);
        while self.buf.len() >= cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(rec);
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panic while holding one of these mutexes only loses trace records;
    // recover the data rather than poisoning all future tracing.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Stable track id for `name`, allocating on first use. Same name → same
/// track (how a session keeps one timeline across preempt/requeue).
pub fn named_track(name: &str) -> u32 {
    let mut tracks = lock(&TRACKS);
    if let Some(i) = tracks.iter().position(|n| n == name) {
        return (i + 1) as u32;
    }
    tracks.push(name.to_string());
    tracks.len() as u32
}

/// The per-session track, named `session-<id>`.
pub fn session_track(id: u64) -> u32 {
    named_track(&format!("session-{id}"))
}

/// A fresh track for the current thread, display-name deduplicated so two
/// unnamed threads don't merge into one lane.
fn unique_track(label: &str) -> u32 {
    let mut tracks = lock(&TRACKS);
    let mut name = label.to_string();
    let mut k = 1;
    while tracks.iter().any(|n| n == &name) {
        k += 1;
        name = format!("{label} #{k}");
    }
    tracks.push(name);
    tracks.len() as u32
}

/// Run `f` with this thread's (track, ring), registering both on first use.
fn with_local_ring<R>(f: impl FnOnce(u32, &mut Ring) -> R) -> R {
    LOCAL_RING.with(|cell| {
        let (track, ring) = cell.get_or_init(|| {
            let label = match std::thread::current().name() {
                Some(name) => name.to_string(),
                None => "thread".to_string(),
            };
            let track = unique_track(&label);
            let ring = Arc::new(Mutex::new(Ring { buf: VecDeque::new(), dropped: 0 }));
            lock(&RINGS).push(Arc::clone(&ring));
            (track, ring)
        });
        f(*track, &mut lock(ring))
    })
}

/// The current thread's track id (registers the thread on first use).
pub fn current_track() -> u32 {
    with_local_ring(|track, _| track)
}

/// `Some(now_micros)` when tracing is on, else `None` — the open half of a
/// manually closed span (`let t0 = trace::start(); … complete_here(…)`).
#[inline]
pub fn start() -> Option<u64> {
    if enabled() {
        Some(clock::now_micros())
    } else {
        None
    }
}

/// RAII span on the current thread's track: opens at [`span`], records on
/// drop. Disabled, construction is the one atomic load and drop is free.
pub struct Span {
    t0_us: Option<u64>,
    cat: &'static str,
    name: &'static str,
    args: Vec<(&'static str, f64)>,
}

/// Open a [`Span`]; attach annotations with [`Span::arg`].
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    Span { t0_us: start(), cat, name, args: Vec::new() }
}

impl Span {
    /// Attach a numeric annotation (no-op while disabled).
    pub fn arg(mut self, key: &'static str, value: f64) -> Span {
        if self.t0_us.is_some() {
            self.args.push((key, value));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.t0_us {
            let t1 = clock::now_micros();
            let args = std::mem::take(&mut self.args);
            record(EventKind::Complete, None, self.cat, self.name, t0, t1, args);
        }
    }
}

/// Record a complete span on the current thread's track, closing at "now".
/// `t0_us` comes from an earlier [`start`] (which already checked the
/// enable flag, so a `Some` here records unconditionally).
pub fn complete_here(
    cat: &'static str,
    name: &'static str,
    t0_us: u64,
    args: &[(&'static str, f64)],
) {
    let t1 = clock::now_micros();
    record(EventKind::Complete, None, cat, name, t0_us, t1, args.to_vec());
}

/// Record a complete span with explicit bounds on an explicit track (how
/// the engine thread writes session-lifecycle spans). Checks the enable
/// flag itself.
pub fn complete(
    track: u32,
    cat: &'static str,
    name: &'static str,
    t0_us: u64,
    t1_us: u64,
    args: &[(&'static str, f64)],
) {
    if !enabled() {
        return;
    }
    record(EventKind::Complete, Some(track), cat, name, t0_us, t1_us, args.to_vec());
}

/// Record a point-in-time marker on `track` at "now". Checks the enable
/// flag itself.
pub fn instant(track: u32, cat: &'static str, name: &'static str, args: &[(&'static str, f64)]) {
    if !enabled() {
        return;
    }
    let ts = clock::now_micros();
    record(EventKind::Instant, Some(track), cat, name, ts, ts, args.to_vec());
}

fn record(
    kind: EventKind,
    track: Option<u32>,
    cat: &'static str,
    name: &'static str,
    t0_us: u64,
    t1_us: u64,
    args: Vec<(&'static str, f64)>,
) {
    with_local_ring(|own_track, ring| {
        ring.push(SpanRecord {
            kind,
            cat,
            name,
            track: track.unwrap_or(own_track),
            ts_us: t0_us,
            dur_us: t1_us.saturating_sub(t0_us),
            args,
        });
    });
}

/// Everything the exporters need: the drained records, the track-name
/// table, and how many records the rings dropped (overwrote) getting here.
pub struct TraceSnapshot {
    /// `(track id, display name)` for every allocated track.
    pub tracks: Vec<(u32, String)>,
    pub records: Vec<SpanRecord>,
    pub dropped: u64,
}

/// Drain every thread's ring into one snapshot. Tracks persist (ids stay
/// stable for live threads/sessions); records and dropped counts reset.
pub fn snapshot_and_drain() -> TraceSnapshot {
    let rings: Vec<Arc<Mutex<Ring>>> = lock(&RINGS).clone();
    let mut records = Vec::new();
    let mut dropped = 0;
    for ring in rings {
        let mut ring = lock(&ring);
        records.extend(ring.buf.drain(..));
        dropped += ring.dropped;
        ring.dropped = 0;
    }
    let tracks = lock(&TRACKS)
        .iter()
        .enumerate()
        .map(|(i, name)| ((i + 1) as u32, name.clone()))
        .collect();
    TraceSnapshot { tracks, records, dropped }
}

/// Discard all buffered records and dropped counts (start a clean capture).
pub fn reset() {
    snapshot_and_drain();
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Tests that flip the global enable flag or ring capacity serialize on
    /// this (shared with the clock/export tests that trace).
    pub(crate) static OBS_TEST_LOCK: Mutex<()> = Mutex::new(());

    fn drain_mine(name: &'static str) -> Vec<SpanRecord> {
        snapshot_and_drain().records.into_iter().filter(|r| r.name == name).collect()
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = lock(&OBS_TEST_LOCK);
        set_enabled(false);
        reset();
        {
            let _s = span("test", "disabled_span_probe").arg("x", 1.0);
        }
        complete(current_track(), "test", "disabled_span_probe", 0, 5, &[]);
        instant(current_track(), "test", "disabled_span_probe", &[]);
        assert!(drain_mine("disabled_span_probe").is_empty());
    }

    #[test]
    fn enabled_span_records_bounds_and_args() {
        let _g = lock(&OBS_TEST_LOCK);
        set_enabled(true);
        reset();
        {
            let _s = span("test", "enabled_span_probe").arg("rows", 4.0);
        }
        set_enabled(false);
        let got = drain_mine("enabled_span_probe");
        assert_eq!(got.len(), 1);
        let r = &got[0];
        assert_eq!(r.kind, EventKind::Complete);
        assert_eq!(r.cat, "test");
        assert_eq!(r.args, vec![("rows", 4.0)]);
        assert_eq!(r.track, current_track());
    }

    #[test]
    fn ring_wraparound_drops_oldest_and_counts() {
        let _g = lock(&OBS_TEST_LOCK);
        set_enabled(true);
        reset();
        set_ring_capacity(8);
        for i in 0..20u64 {
            complete(current_track(), "test", "wrap_probe", i, i + 1, &[("i", i as f64)]);
        }
        set_enabled(false);
        let snap = snapshot_and_drain();
        set_ring_capacity(DEFAULT_RING_CAPACITY);
        let mine: Vec<_> = snap.records.iter().filter(|r| r.name == "wrap_probe").collect();
        // capacity 8: records 0..12 were overwritten, 12..20 survive in order
        assert_eq!(mine.len(), 8);
        let ts: Vec<u64> = mine.iter().map(|r| r.ts_us).collect();
        assert_eq!(ts, (12..20).collect::<Vec<u64>>());
        assert!(snap.dropped >= 12, "dropped={} < 12", snap.dropped);
    }

    #[test]
    fn named_tracks_are_stable_and_unique_tracks_are_not() {
        let a = named_track("obs-test-stable-track");
        let b = named_track("obs-test-stable-track");
        assert_eq!(a, b);
        let c = unique_track("obs-test-stable-track");
        assert_ne!(a, c);
        assert_eq!(session_track(987_654), session_track(987_654));
    }

    #[test]
    fn instant_records_zero_duration() {
        let _g = lock(&OBS_TEST_LOCK);
        set_enabled(true);
        reset();
        instant(current_track(), "test", "instant_probe", &[("v", 2.0)]);
        set_enabled(false);
        let got = drain_mine("instant_probe");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].kind, EventKind::Instant);
        assert_eq!(got[0].dur_us, 0);
    }
}
