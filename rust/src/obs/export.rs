//! Exporters: Chrome trace-event JSON and Prometheus text exposition.
//!
//! [`chrome_trace_json`] renders a [`TraceSnapshot`] in the Chrome
//! trace-event format (the JSON-object flavour: `{"traceEvents": […]}`),
//! loadable in Perfetto or `chrome://tracing`. All events live in one
//! process (`pid` 1); each trace track becomes a `tid` with a
//! `thread_name` metadata record, so worker threads and decode sessions
//! each get their own named row. Spans are complete (`ph:"X"`) events with
//! microsecond `ts`/`dur`, emitted in sorted timestamp order; markers are
//! thread-scoped instant (`ph:"i"`) events.
//!
//! [`prometheus_text`] renders a [`Registry`] in the Prometheus text
//! exposition format (version 0.0.4): `# HELP`/`# TYPE` headers, plain
//! counter/gauge samples, and histograms as cumulative `_bucket{le="…"}`
//! series plus `_sum`/`_count`.
//!
//! [`validate_json`] is a dependency-free structural JSON check used by
//! the golden-shape tests (the repo vendors no JSON parser).

use std::fmt::Write as _;

use crate::obs::metrics::{Metric, Registry};
use crate::obs::trace::{EventKind, TraceSnapshot};

/// Render `v` as a JSON-safe number literal (no NaN/inf, no exponent).
fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    if v == v.trunc() && v.abs() < 9e15 {
        return format!("{}", v as i64);
    }
    format!("{v}")
}

/// Escape `s` for a JSON string literal (quotes, backslashes, control).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn args_json(args: &[(&'static str, f64)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{}\":{}", esc(k), fmt_num(*v));
    }
    out.push('}');
    out
}

/// Render a drained trace as Chrome trace-event JSON (see module docs).
pub fn chrome_trace_json(snap: &TraceSnapshot) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |out: &mut String, first: &mut bool, ev: String| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(&ev);
    };

    push(
        &mut out,
        &mut first,
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"args\":{\"name\":\"llm-datatypes\"}}"
            .to_string(),
    );
    let mut tracks = snap.tracks.clone();
    tracks.sort_by_key(|(id, _)| *id);
    for (id, name) in &tracks {
        push(
            &mut out,
            &mut first,
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{id},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                esc(name)
            ),
        );
    }

    let mut records: Vec<_> = snap.records.iter().collect();
    records.sort_by_key(|r| (r.ts_us, r.track, r.dur_us));
    for r in records {
        let ev = match r.kind {
            EventKind::Complete => format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{}}}",
                esc(r.name),
                esc(r.cat),
                r.ts_us,
                r.dur_us,
                r.track,
                args_json(&r.args)
            ),
            EventKind::Instant => format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{}}}",
                esc(r.name),
                esc(r.cat),
                r.ts_us,
                r.track,
                args_json(&r.args)
            ),
        };
        push(&mut out, &mut first, ev);
    }
    let _ = writeln!(out, "\n],\"droppedEvents\":{}}}", snap.dropped);
    out
}

/// Render a metrics registry as Prometheus text exposition (see module
/// docs). Histogram bucket bounds are scaled by the entry's `scale`
/// (recorded-unit → exported-unit, e.g. µs → s).
pub fn prometheus_text(reg: &Registry) -> String {
    let mut out = String::new();
    for e in reg.entries() {
        let _ = writeln!(out, "# HELP {} {}", e.name, e.help);
        match &e.metric {
            Metric::Counter(v) => {
                let _ = writeln!(out, "# TYPE {} counter\n{} {}", e.name, e.name, v);
            }
            Metric::Gauge(v) => {
                let _ = writeln!(out, "# TYPE {} gauge\n{} {}", e.name, e.name, fmt_num(*v));
            }
            Metric::Histogram { hist, scale } => {
                let _ = writeln!(out, "# TYPE {} histogram", e.name);
                for (upper, cum) in hist.cumulative() {
                    let le = fmt_num(upper as f64 * scale);
                    let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", e.name, le, cum);
                }
                let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", e.name, hist.count());
                let _ = writeln!(out, "{}_sum {}", e.name, fmt_num(hist.sum() as f64 * scale));
                let _ = writeln!(out, "{}_count {}", e.name, hist.count());
            }
        }
    }
    out
}

/// Structural JSON validation: full grammar (objects, arrays, strings with
/// escapes, numbers, literals), no value materialization. Returns the byte
/// offset and cause on malformed input.
pub fn validate_json(s: &str) -> Result<(), String> {
    let mut p = JsonChecker { b: s.as_bytes(), i: 0, depth: 0 };
    p.ws();
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(())
}

struct JsonChecker<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl JsonChecker<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.i)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<(), String> {
        if self.depth > 128 {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string(),
            Some(b't') => self.literal("true"),
            Some(b'f') => self.literal("false"),
            Some(b'n') => self.literal("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<(), String> {
        self.depth += 1;
        self.eat(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.depth += 1;
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<(), String> {
        self.eat(b'"')?;
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(()),
                b'\\' => match self.peek() {
                    Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => self.i += 1,
                    Some(b'u') => {
                        self.i += 1;
                        for _ in 0..4 {
                            match self.peek() {
                                Some(h) if h.is_ascii_hexdigit() => self.i += 1,
                                _ => return Err(self.err("bad \\u escape")),
                            }
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("raw control character in string")),
                _ => {}
            }
        }
        Err(self.err("unterminated string"))
    }

    fn number(&mut self) -> Result<(), String> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits = |p: &mut Self| -> Result<(), String> {
            let start = p.i;
            while matches!(p.peek(), Some(c) if c.is_ascii_digit()) {
                p.i += 1;
            }
            if p.i == start {
                Err(p.err("expected digits"))
            } else {
                Ok(())
            }
        };
        digits(self)?;
        if self.peek() == Some(b'.') {
            self.i += 1;
            digits(self)?;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            digits(self)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Histogram;
    use crate::obs::trace::SpanRecord;

    #[test]
    fn json_validator_accepts_and_rejects() {
        for ok in [
            "{}",
            "[]",
            "null",
            "-12.5e-3",
            "\"a \\\"quoted\\\" \\u00e9 string\"",
            "{\"a\":[1,2,{\"b\":null}],\"c\":true}",
        ] {
            assert!(validate_json(ok).is_ok(), "{ok}");
        }
        for bad in ["", "{", "[1,]", "{\"a\":}", "01a", "\"unterminated", "{}extra", "[1 2]"] {
            assert!(validate_json(bad).is_err(), "{bad}");
        }
    }

    fn rec(kind: EventKind, name: &'static str, track: u32, ts: u64, dur: u64) -> SpanRecord {
        SpanRecord { kind, cat: "test", name, track, ts_us: ts, dur_us: dur, args: vec![("rows", 2.0)] }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_sorted_complete_events() {
        let snap = TraceSnapshot {
            tracks: vec![(2, "session-1".to_string()), (1, "engine \"main\"".to_string())],
            records: vec![
                rec(EventKind::Complete, "late", 1, 90, 5),
                rec(EventKind::Complete, "early", 2, 10, 40),
                rec(EventKind::Instant, "mark", 2, 50, 0),
            ],
            dropped: 3,
        };
        let json = chrome_trace_json(&snap);
        validate_json(&json).unwrap();
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"name\":\"session-1\""));
        assert!(json.contains("engine \\\"main\\\""), "track names are escaped");
        assert!(json.contains("\"droppedEvents\":3"));
        // events are sorted by timestamp: "early" (ts 10) before "late" (ts 90)
        assert!(json.find("\"early\"").unwrap() < json.find("\"late\"").unwrap());
        assert!(json.contains("\"ph\":\"X\",\"ts\":10,\"dur\":40"));
        assert!(json.contains("\"ph\":\"i\""));
    }

    #[test]
    fn prometheus_text_renders_all_metric_kinds() {
        let mut hist = Histogram::new();
        for v in [1_000u64, 2_000, 2_000, 40_000] {
            hist.record(v);
        }
        let mut reg = Registry::new();
        reg.counter("llmdt_steps_total", "Engine steps.", 7);
        reg.gauge("llmdt_pages_in_use", "Held KV pages.", 5.0);
        reg.histogram("llmdt_ttft_seconds", "TTFT.", hist, 1e-6);
        let text = prometheus_text(&reg);
        assert!(text.contains("# TYPE llmdt_steps_total counter\nllmdt_steps_total 7\n"));
        assert!(text.contains("# TYPE llmdt_pages_in_use gauge\nllmdt_pages_in_use 5\n"));
        assert!(text.contains("# TYPE llmdt_ttft_seconds histogram\n"));
        assert!(text.contains("llmdt_ttft_seconds_bucket{le=\"+Inf\"} 4\n"));
        assert!(text.contains("llmdt_ttft_seconds_count 4\n"));
        // buckets are cumulative and scaled into seconds (µs * 1e-6 < 1)
        let bucket_lines: Vec<&str> =
            text.lines().filter(|l| l.starts_with("llmdt_ttft_seconds_bucket{le=\"0")).collect();
        assert!(!bucket_lines.is_empty());
        let counts: Vec<u64> = bucket_lines
            .iter()
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
        assert_eq!(*counts.last().unwrap(), 4);
        assert!(text.ends_with('\n'));
    }
}
