//! The engine's single monotonic time source, with test injection.
//!
//! Production call sites use [`now`] wherever they previously called
//! `Instant::now()`. By default that *is* `Instant::now()`; a test can
//! switch its own thread onto a fake clock ([`fake`]) that only moves when
//! [`advance`] is called, making TTFT/ITL metrics and span timelines exact.
//!
//! The fake clock is thread-local on purpose: parallel tests in one binary
//! cannot perturb each other, and the engine paths a deterministic test
//! drives (`Engine::submit` / `Engine::step`) run on the caller's thread.
//!
//! Timestamps for trace records are microseconds since a process-wide
//! [`epoch`] (first observed instant), so every thread's spans share one
//! timeline.

use std::cell::Cell;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static FAKE_OFFSET: Cell<Option<Duration>> = const { Cell::new(None) };
}

/// Process-wide reference instant; first call pins it. All trace
/// timestamps are measured from here.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic "now": the fake clock's position when this thread has one
/// ([`fake`]), otherwise `Instant::now()`.
pub fn now() -> Instant {
    match FAKE_OFFSET.with(Cell::get) {
        Some(offset) => epoch() + offset,
        None => Instant::now(),
    }
}

/// Microseconds from [`epoch`] to [`now`] — the trace timestamp unit.
pub fn now_micros() -> u64 {
    micros_since_epoch(now())
}

/// Microseconds from [`epoch`] to `t` (zero for pre-epoch instants).
pub fn micros_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

/// True while this thread is on the fake clock.
pub fn is_fake() -> bool {
    FAKE_OFFSET.with(Cell::get).is_some()
}

/// Advance this thread's fake clock. Panics if [`fake`] is not active —
/// advancing real time is always a bug.
pub fn advance(d: Duration) {
    FAKE_OFFSET.with(|f| {
        let cur = f.get().expect("clock::advance without an active fake clock");
        f.set(Some(cur + d));
    });
}

/// Put this thread on a fake clock starting at [`epoch`]; time then moves
/// only via [`advance`]. Dropping the guard returns the thread to real
/// time.
pub fn fake() -> FakeClockGuard {
    epoch(); // pin the reference before anything is measured against it
    FAKE_OFFSET.with(|f| f.set(Some(Duration::ZERO)));
    FakeClockGuard { _priv: () }
}

/// RAII handle for a thread's fake clock (see [`fake`]).
pub struct FakeClockGuard {
    _priv: (),
}

impl Drop for FakeClockGuard {
    fn drop(&mut self) {
        FAKE_OFFSET.with(|f| f.set(None));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic_and_post_epoch() {
        let a = now();
        let b = now();
        assert!(b >= a);
        assert!(micros_since_epoch(b) >= micros_since_epoch(a));
    }

    #[test]
    fn fake_clock_moves_only_on_advance() {
        let _guard = fake();
        assert!(is_fake());
        let t0 = now();
        assert_eq!(now(), t0, "fake time is frozen between advances");
        advance(Duration::from_millis(5));
        assert_eq!(now().duration_since(t0), Duration::from_millis(5));
        advance(Duration::from_micros(250));
        assert_eq!(now().duration_since(t0), Duration::from_micros(5250));
    }

    #[test]
    fn fake_clock_guard_restores_real_time() {
        {
            let _guard = fake();
            advance(Duration::from_secs(3600));
        }
        assert!(!is_fake());
        // back on real time: an hour has not actually passed
        assert!(now().saturating_duration_since(epoch()) < Duration::from_secs(3600));
    }

    #[test]
    #[should_panic(expected = "without an active fake clock")]
    fn advance_without_fake_panics() {
        advance(Duration::from_millis(1));
    }
}
