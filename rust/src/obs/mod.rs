//! Observability: low-overhead tracing + metrics for the serving engine.
//!
//! Three layers, each usable alone:
//!
//! - [`clock`] — the single monotonic time source every timing call site in
//!   the engine goes through. Tests inject a fake, thread-local clock
//!   ([`clock::fake`]) and advance it explicitly, making latency metrics and
//!   span timelines deterministic.
//! - [`trace`] — span/event records in per-thread ring buffers (bounded,
//!   drop-oldest) behind a single global enable flag. The disabled path is
//!   one relaxed atomic load per span site; no clock read, no allocation,
//!   no lock. Enabled, a span costs one clock read at open and a ring push
//!   under an uncontended thread-local mutex at close.
//! - [`metrics`] — named counters, gauges, and log-bucketed histograms
//!   ([`metrics::Histogram`]: O(buckets) memory however many samples are
//!   recorded, ≤ 25 % relative bucket width) assembled into a
//!   [`metrics::Registry`] snapshot for export.
//!
//! [`export`] renders a [`trace::TraceSnapshot`] as Chrome trace-event JSON
//! (loadable in Perfetto / `chrome://tracing`; one track per worker thread,
//! one per decode session) and a [`metrics::Registry`] as Prometheus text
//! exposition. `serve-decode --trace-out/--metrics-out` and the perf
//! harnesses wire both to files.
//!
//! Instrumentation is observation-only by contract: enabling tracing must
//! not change a single emitted token or logprob bit (pinned by the
//! `obs_trace` integration tests and tracing-enabled variants of the
//! bit-identity property suites).

pub mod clock;
pub mod export;
pub mod metrics;
pub mod trace;
