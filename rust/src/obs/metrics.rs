//! Log-bucketed histograms and a named-metric registry.
//!
//! [`Histogram`] is an HDR-style log-linear histogram over `u64` samples:
//! each power-of-two octave splits into 4 linear sub-buckets, so relative
//! bucket width is at most 25 % and the whole `u64` range fits in
//! [`BUCKETS`] counters — memory is O(buckets) no matter how many samples
//! are recorded, which is what lets `MetricsCollector` retire its unbounded
//! per-token `Vec`s. Percentiles are nearest-rank over the bucket counts,
//! clamped into the observed `[min, max]`, so a reported quantile is always
//! within one bucket width of the true sample.
//!
//! [`Registry`] is a flat snapshot of named counters / gauges / histograms
//! assembled at export time; [`crate::obs::export::prometheus_text`]
//! renders it as Prometheus text exposition.

/// Total bucket count: values 0–3 exactly, then 4 sub-buckets for each of
/// the remaining 62 octaves (top index is `bucket_index(u64::MAX)` = 251).
pub const BUCKETS: usize = 252;

/// Bucket index for a sample; monotone in `v`.
pub fn bucket_index(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as usize; // >= 2
    (octave - 1) * 4 + ((v >> (octave - 2)) & 3) as usize
}

/// Half-open value range `[lo, hi)` covered by bucket `i` (`hi` saturates
/// at the top of the `u64` range).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    assert!(i < BUCKETS, "bucket {i} out of range");
    if i < 4 {
        return (i as u64, i as u64 + 1);
    }
    let octave = i / 4 + 1;
    let sub = (i % 4) as u64;
    let lo = (4 + sub) << (octave - 2);
    (lo, lo.saturating_add(1 << (octave - 2)))
}

/// Bounded-memory histogram of `u64` samples (see module docs).
#[derive(Clone)]
pub struct Histogram {
    counts: Box<[u64; BUCKETS]>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: Box::new([0; BUCKETS]), count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample.
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile over the bucket counts, `q` in [0, 1]. The
    /// result is the rank's bucket lower bound clamped into the observed
    /// `[min, max]`: within one bucket width of the true sample, and exact
    /// for single-sample and sub-4 values. Empty histograms report 0.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64 * q.clamp(0.0, 1.0)).ceil() as u64).clamp(1, self.count);
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bounds(i).0.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// `(bucket upper bound, cumulative count)` for every non-empty
    /// bucket, in value order — the Prometheus `_bucket{le=…}` series.
    pub fn cumulative(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                seen += c;
                out.push((bucket_bounds(i).1, seen));
            }
        }
        out
    }
}

/// One exported series.
pub enum Metric {
    /// Monotone event count.
    Counter(u64),
    /// Point-in-time value.
    Gauge(f64),
    /// Distribution; `scale` converts recorded units to exported units
    /// (1e-6 turns recorded microseconds into Prometheus-idiomatic
    /// seconds).
    Histogram { hist: Histogram, scale: f64 },
}

/// A named metric with help text.
pub struct Entry {
    pub name: String,
    pub help: String,
    pub metric: Metric,
}

/// Flat, ordered snapshot of named metrics for export.
#[derive(Default)]
pub struct Registry {
    entries: Vec<Entry>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.push(name, help, Metric::Counter(value));
    }

    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.push(name, help, Metric::Gauge(value));
    }

    pub fn histogram(&mut self, name: &str, help: &str, hist: Histogram, scale: f64) {
        self.push(name, help, Metric::Histogram { hist, scale });
    }

    fn push(&mut self, name: &str, help: &str, metric: Metric) {
        self.entries.push(Entry { name: name.to_string(), help: help.to_string(), metric });
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.entries.iter().find(|e| e.name == name).map(|e| &e.metric)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_partition_the_u64_range() {
        // indices are monotone, contiguous, and bounds invert the index
        let mut prev = None;
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert!(lo < hi || hi == u64::MAX);
            assert_eq!(bucket_index(lo), i, "lower bound maps back to its bucket");
            if let Some(p) = prev {
                assert_eq!(lo, p, "bucket {i} not contiguous");
            }
            prev = Some(hi);
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // exact region: one bucket per value below 4
        for v in 0..4 {
            assert_eq!(bucket_bounds(bucket_index(v)), (v, v + 1));
        }
    }

    #[test]
    fn record_percentile_round_trip_within_one_bucket_width() {
        for v in [0, 1, 3, 4, 7, 13, 100, 10_000, 123_456, u64::MAX / 3] {
            let mut h = Histogram::new();
            h.record(v);
            // single sample: clamp to [min, max] makes every quantile exact
            for q in [0.0, 0.5, 1.0] {
                assert_eq!(h.percentile(q), v, "v={v} q={q}");
            }
        }
        // multi-sample: each quantile lands within its bucket's width
        let mut h = Histogram::new();
        let samples: Vec<u64> = (0..1000).map(|i| i * i).collect();
        for &s in &samples {
            h.record(s);
        }
        for q in [0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let got = h.percentile(q);
            let idx = bucket_index(got);
            let (lo, hi) = bucket_bounds(idx);
            let width = hi - lo;
            let rank = ((samples.len() as f64 * q).ceil() as usize).clamp(1, samples.len());
            let exact = samples[rank - 1]; // samples are already sorted
            assert!(
                got <= exact && exact.saturating_sub(got) <= width,
                "q={q}: got {got} exact {exact} width {width}"
            );
        }
    }

    #[test]
    fn percentile_edge_ranks() {
        let mut h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0, "empty histogram reports zero");
        for v in [1u64, 2, 3] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), 1, "q=0 is the minimum");
        assert_eq!(h.percentile(1.0), 3, "q=1 is the maximum");
        assert_eq!(h.percentile(0.5), 2);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 6);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 3);
        assert!((h.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cumulative_counts_are_monotone_and_total() {
        let mut h = Histogram::new();
        for v in [1u64, 1, 5, 900, 900, 900, 1_000_000] {
            h.record(v);
        }
        let cum = h.cumulative();
        assert!(!cum.is_empty());
        assert!(cum.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 <= w[1].1));
        assert_eq!(cum.last().unwrap().1, h.count());
    }

    #[test]
    fn registry_orders_and_finds_entries() {
        let mut reg = Registry::new();
        reg.counter("a_total", "a", 3);
        reg.gauge("b", "b", 1.5);
        reg.histogram("c_seconds", "c", Histogram::new(), 1e-6);
        assert_eq!(reg.entries().len(), 3);
        assert!(matches!(reg.get("a_total"), Some(Metric::Counter(3))));
        assert!(matches!(reg.get("b"), Some(Metric::Gauge(v)) if *v == 1.5));
        assert!(reg.get("missing").is_none());
    }
}
