"""L2 model checks: shapes, kernel-path equivalence, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import formats as F
from compile import model as M

CFG = M.ZOO["nano"]
REG = F.registry()


def _rand_tokens(rng, b, s):
    return jnp.asarray(rng.integers(0, CFG.vocab, (b, s)).astype(np.int32))


def _quant_params(rng, cfg, w4a4=False):
    """Random-but-valid quantized parameter set (codes/scales/codebook)."""
    p = {}
    for name, shape, dtype in M.quant_param_specs(cfg, w4a4=w4a4):
        if dtype == "i8":
            p[name] = jnp.asarray(rng.integers(0, 16, shape).astype(np.int8))
        elif name.endswith(".scales"):
            p[name] = jnp.asarray(
                rng.uniform(0.01, 0.05, shape).astype(np.float32))
        elif name.endswith(".smooth"):
            p[name] = jnp.ones(shape, jnp.float32)
        elif name == "codebook":
            p[name] = jnp.asarray(REG["sf4"].padded())
        elif name == "act_codebook":
            p[name] = jnp.asarray(REG["int4"].padded())
        else:
            init = M.init_params(cfg, jax.random.PRNGKey(0))
            p[name] = init[name]
    return p


def test_fp32_forward_shape():
    p = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tok = _rand_tokens(rng, 2, CFG.seq)
    logits = M.lm_forward(CFG, p, tok, quant=False, use_pallas=False)
    assert logits.shape == (2, CFG.seq, CFG.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("w4a4", [False, True])
def test_quant_forward_pallas_matches_ref(w4a4):
    """Full quantized model: pallas kernel path == jnp oracle path."""
    rng = np.random.default_rng(1)
    p = _quant_params(rng, CFG, w4a4=w4a4)
    tok = _rand_tokens(rng, 2, CFG.seq)
    a = M.lm_forward(CFG, p, tok, quant=True, w4a4=w4a4, use_pallas=True)
    b = M.lm_forward(CFG, p, tok, quant=True, w4a4=w4a4, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_loss_is_log_vocab_at_init():
    """Untrained model ~ uniform predictions: nll/token ~= ln(V)."""
    p = M.init_params(CFG, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    tok = _rand_tokens(rng, 4, CFG.seq + 1)
    s, n = M.lm_loss(CFG, p, tok, quant=False, use_pallas=False)
    per_tok = float(s) / float(n)
    assert abs(per_tok - np.log(CFG.vocab)) < 2.0


def test_train_step_decreases_loss():
    p = M.init_params(CFG, jax.random.PRNGKey(2))
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(w) for k, w in p.items()}
    rng = np.random.default_rng(3)
    # one repeated batch: loss must drop fast if bwd+AdamW are correct
    tok = _rand_tokens(rng, CFG.batch_train, CFG.seq + 1)
    step_fn = jax.jit(lambda p, m, v, s, t: M.train_step(CFG, p, m, v, s, t))
    losses = []
    for i in range(25):
        loss, p, m, v = step_fn(p, m, v, jnp.float32(i), tok)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_quant_identity_codebook_approximates_fp32():
    """int8 codebook + RTN-coded weights ~ fp32 model (sanity of wiring)."""
    fp = M.init_params(CFG, jax.random.PRNGKey(3))
    cb = REG["int8"].as_array()
    q = {}
    for name, shape, dtype in M.quant_param_specs(CFG):
        if name == "codebook":
            # int8 has 256 values; use a 16-entry slice around zero instead:
            continue
        base = name.rsplit(".", 1)[0]
        if dtype == "i8" or name.endswith(".scales"):
            continue
        q[name] = fp[name]
    # Quantize each linear with int4 codebook per-column absmax (block=K).
    cb16 = REG["int4"].as_array()
    q["codebook"] = jnp.asarray(REG["int4"].padded())
    for lname, shape in M.param_specs(CFG):
        if lname.split(".")[-1] not in M.QUANT_LINEARS:
            continue
        w = np.asarray(fp[lname])  # [K, N]
        absmax = np.abs(w).max(axis=0, keepdims=True) + 1e-12
        scale = absmax / np.max(np.abs(cb16))
        wn = w / scale
        idx = np.argmin(np.abs(wn[..., None] - cb16[None, None]), axis=-1)
        q[f"{lname}.codes"] = jnp.asarray(idx.astype(np.int8))
        q[f"{lname}.scales"] = jnp.asarray(
            np.broadcast_to(scale, w.shape).astype(np.float32))
    rng = np.random.default_rng(5)
    tok = _rand_tokens(rng, 2, CFG.seq)
    lf = M.lm_forward(CFG, fp, tok, quant=False, use_pallas=False)
    lq = M.lm_forward(CFG, q, tok, quant=True, use_pallas=False)
    # int4 fake-quant of a *random-init* model perturbs logits only mildly
    err = np.max(np.abs(np.asarray(lf) - np.asarray(lq)))
    rel = err / (np.max(np.abs(np.asarray(lf))) + 1e-9)
    assert rel < 0.6, rel


def test_param_specs_counts():
    for cfg in M.ZOO.values():
        specs = M.param_specs(cfg)
        assert len(specs) == 2 + 10 * cfg.n_layers + 3
        qspecs = M.quant_param_specs(cfg)
        n_lin = 6 * cfg.n_layers
        assert len(qspecs) == len(specs) + n_lin + 1
        w4 = M.quant_param_specs(cfg, w4a4=True)
        assert len(w4) == len(qspecs) + n_lin + 1


def test_classifier_shapes_and_training():
    for cfg in M.CLS_ZOO.values():
        p = M.cls_init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(
            (8, cfg.image * cfg.image)).astype(np.float32))
        logits = M.cls_forward(cfg, p, x, quant=False, use_pallas=False)
        assert logits.shape == (8, cfg.classes)
        # one class separable by mean: loss decreases
        labels = jnp.asarray((np.asarray(x).mean(axis=1) > 0).astype(np.int32))
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(w) for k, w in p.items()}
        l0 = None
        for i in range(30):
            loss, p, m, v = M.cls_train_step(cfg, p, m, v, jnp.float32(i),
                                             x, labels)
            l0 = l0 if l0 is not None else float(loss)
        assert float(loss) < l0
