"""Golden + invariant tests for the datatype zoo (paper Table 15)."""

import numpy as np
import pytest

from compile import formats as F

REG = F.registry()

# Paper Table 15 rows (raw values normalized to max |v| = 1).
GOLDEN = {
    "nf4": [-1.000, -0.696, -0.525, -0.395, -0.284, -0.185, -0.091, 0.000,
            0.080, 0.161, 0.246, 0.338, 0.441, 0.563, 0.723, 1.000],
    "int4": [v / 8.0 for v in range(-8, 8)],
    "e2m1": [v / 6.0 for v in
             [-6, -4, -3, -2, -1.5, -1, -0.5, 0, 0.5, 1, 1.5, 2, 3, 4, 6]],
    "e2m1_i": [v / 6.0 for v in
               [-6, -4, -3, -2, -1.5, -1, -0.0625, 0, 0.0625, 1, 1.5, 2, 3, 4, 6]],
    "e2m1_b": [v / 12.0 for v in
               [-12, -8, -6, -4, -3, -2, -0.0625, 0, 0.0625, 2, 3, 4, 6, 8, 12]],
    "e2m1_sp": [v / 6.0 for v in
                [-6, -4, -3, -2, -1.5, -1, -0.5, 0, 0.5, 1, 1.5, 2, 3, 4, 5, 6]],
    "e2m1_sr": [v / 8.0 for v in
                [-6, -4, -3, -2, -1.5, -1, -0.5, 0, 0.5, 1, 1.5, 2, 3, 4, 6, 8]],
    "e3m0": [v / 16.0 for v in
             [-16, -8, -4, -2, -1, -0.5, -0.25, 0, 0.25, 0.5, 1, 2, 4, 8, 16]],
    "apot4": [-1.0, -0.8, -0.6, -0.4, -0.3, -0.2, -0.1, 0.0,
              0.1, 0.2, 0.3, 0.4, 0.6, 0.8, 1.0],
    "apot4_sp": [-1.0, -0.8, -0.6, -0.4, -0.3, -0.2, -0.1, 0.0,
                 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0],
}

# SF4 per-nu spot values from Table 15 (second value and second-to-last).
SF4_SPOTS = {3: (-0.576, 0.606), 4: (-0.609, 0.638),
             5: (-0.628, 0.657), 6: (-0.640, 0.669)}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_golden_values(name):
    got = REG[name].as_array()
    want = np.array(GOLDEN[name])
    assert got.shape == want.shape, (name, got.shape, want.shape)
    np.testing.assert_allclose(got, want, atol=2e-3)


@pytest.mark.parametrize("nu", sorted(SF4_SPOTS))
def test_sf4_spot_values(nu):
    cb = REG[f"sf4_v{nu}"].as_array()
    lo, hi = SF4_SPOTS[nu]
    assert abs(cb[1] - lo) < 1e-3
    assert abs(cb[-2] - hi) < 1e-3


def test_sf4_v4_full_positive_side():
    # Table 15 lists the whole positive side for nu=4.
    cb = REG["sf4_v4"].as_array()
    want = [0.062, 0.126, 0.194, 0.270, 0.359, 0.472, 0.638, 1.000]
    np.testing.assert_allclose(cb[8:], want, atol=1e-3)


@pytest.mark.parametrize("name", sorted(REG))
def test_invariants(name):
    spec = REG[name]
    cb = spec.as_array()
    assert np.all(np.diff(cb) > 0), f"{name}: not strictly sorted"
    assert 0.0 in cb, f"{name}: zero is not exactly representable"
    assert np.isclose(np.max(np.abs(cb)), 1.0), f"{name}: not normalized"
    assert spec.n_values <= 2 ** spec.bits


def test_main_formats_value_counts():
    # FP4 wastes one code on -0; supernormal variants recover it (16 values).
    assert REG["e2m1"].n_values == 15
    assert REG["e2m1_sr"].n_values == 16
    assert REG["e2m1_sp"].n_values == 16
    assert REG["apot4"].n_values == 15
    assert REG["apot4_sp"].n_values == 16
    assert REG["nf4"].n_values == 16
    assert REG["sf4"].n_values == 16


def test_supernormal_is_positive_side_only():
    base = set(REG["e2m1"].codebook)
    sp = set(REG["e2m1_sp"].codebook)
    extra = sp - base
    assert len(extra) == 1 and next(iter(extra)) > 0


def test_sf_converges_to_nf():
    """Fig. 4: SF4(nu) -> NF4 as nu -> inf."""
    nf4 = F.normal_float(4)
    d_small = np.max(np.abs(F.student_float(3, 4) - nf4))
    d_big = np.max(np.abs(F.student_float(200, 4) - nf4))
    assert d_big < 0.01
    assert d_big < d_small / 10


def test_algorithm1_positive_bias():
    """More values on the positive side (paper Section 3.3)."""
    for cb in (F.normal_float(4), F.student_float(5, 4), F.normal_float(3)):
        assert (cb > 0).sum() == (cb < 0).sum() + 1


def test_padded_codebook_preserves_quantization():
    spec = REG["nf3"]
    cb, padded = spec.as_array(), spec.padded()
    assert len(padded) == 16
    # nearest-value quantization must agree between raw and padded books
    xs = np.linspace(-1.5, 1.5, 101)
    for x in xs:
        q1 = cb[np.argmin(np.abs(cb - x))]
        q2 = padded[np.argmin(np.abs(padded - x))]
        assert np.isclose(q1, q2)


def test_int_format_shapes():
    assert REG["int3"].n_values == 8
    assert REG["int5"].n_values == 32
    assert REG["e2m0"].n_values == 7


def test_apot_from_sets_matches_paper_sets():
    cb = F.apot_from_sets(F.APOT4_S1, F.APOT4_S2)
    np.testing.assert_allclose(cb, GOLDEN["apot4"], atol=1e-9)


def test_dump_tsv_roundtrip(tmp_path):
    path = tmp_path / "codebooks.tsv"
    F.dump_tsv(str(path))
    lines = [l for l in path.read_text().splitlines() if not l.startswith("#")]
    assert len(lines) == len(REG)
    for line in lines:
        parts = line.split("\t")
        name, bits = parts[0], int(parts[1])
        vals = [float(v) for v in parts[3:]]
        np.testing.assert_allclose(vals, REG[name].as_array(), atol=1e-9)
