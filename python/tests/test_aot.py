"""AOT emission checks: HLO text artifacts + manifests are loader-ready."""

import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    arts = aot.lm_artifacts(M.ZOO["nano"]) + aot.kernel_artifacts()
    for a in arts:
        a.emit(out)
    return out, arts


def test_hlo_text_parses_as_hlo(emitted):
    out, arts = emitted
    for a in arts:
        text = open(os.path.join(out, f"{a.name}.hlo.txt")).read()
        assert text.startswith("HloModule"), a.name
        assert "ENTRY" in text, a.name


def test_no_mosaic_custom_calls(emitted):
    """interpret=True must keep pallas out of Mosaic lowering."""
    out, arts = emitted
    for a in arts:
        text = open(os.path.join(out, f"{a.name}.hlo.txt")).read()
        assert "mosaic" not in text.lower(), a.name


def test_manifest_matches_parameter_count(emitted):
    out, arts = emitted
    for a in arts:
        lines = open(os.path.join(out, f"{a.name}.params.txt")).read().splitlines()
        sep = lines.index("-- outputs --")
        inputs, outputs = lines[:sep], lines[sep + 1:]
        assert len(inputs) == len(a.inputs), a.name
        assert len(outputs) == len(a.output_names), a.name
        # parameter count in the HLO entry computation must agree
        text = open(os.path.join(out, f"{a.name}.hlo.txt")).read()
        entry = text[text.index("ENTRY"):]
        n_params = entry.count(" parameter(")
        assert n_params == len(a.inputs), (a.name, n_params, len(a.inputs))


def test_manifest_shapes_parse(emitted):
    out, arts = emitted
    for a in arts:
        for line in open(os.path.join(out, f"{a.name}.params.txt")):
            line = line.strip()
            if line == "-- outputs --" or not line:
                continue
            parts = line.split(" ")
            name, dtype = parts[0], parts[1]
            dims = parts[2] if len(parts) > 2 else ""  # scalar: no dims field
            assert dtype in ("f32", "i32", "i8"), line
            if dims:
                [int(d) for d in dims.split(",") if d]


def test_train_artifact_io_symmetry(emitted):
    """Train step outputs (params', m', v') must mirror its param inputs so
    the Rust driver can feed outputs back as next-step inputs."""
    out, _ = emitted
    lines = open(os.path.join(out, "lm_train_nano.params.txt")).read().splitlines()
    sep = lines.index("-- outputs --")
    inputs = [l.split(" ") for l in lines[:sep]]
    outputs = [l.split(" ") for l in lines[sep + 1:]]
    # inputs: step, tokens, then 3N tensors; outputs: loss then the same 3N
    assert inputs[0][0] == "step" and inputs[1][0] == "tokens"
    assert outputs[0][0] == "loss"
    assert [i[1:] for i in inputs[2:]] == [o[1:] for o in outputs[1:]]
    assert [i[0] for i in inputs[2:]] == [o[0] for o in outputs[1:]]
