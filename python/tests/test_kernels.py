"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes, block sizes, and codebooks; fixed cases cover the
paper's formats and the degenerate inputs (zero rows, single tiles).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import ref
from compile.kernels.lut_matmul import act_quant, lut_matmul

REG = F.registry()


def _case(seed, m, k, n, block, scale_lo=0.25, scale_hi=4.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    codes = rng.integers(0, 16, (k, n)).astype(np.int32)
    scales = rng.uniform(scale_lo, scale_hi, (k // block, n)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(codes), jnp.asarray(scales)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 200),
    kb=st.integers(1, 6),
    n=st.integers(1, 200),
    block=st.sampled_from([1, 16, 32, 64, 128]),
)
def test_lut_matmul_matches_ref(seed, m, kb, n, block):
    k = kb * block
    x, codes, scales = _case(seed, m, k, n, block)
    cb = jnp.asarray(np.sort(np.random.default_rng(seed).standard_normal(16))
                     .astype(np.float32))
    got = lut_matmul(x, codes, scales, cb, block=block)
    want = ref.lut_matmul(x, codes, scales, cb, block=block)
    # f32 accumulation order differs between the tiled kernel and the oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("fmt", list(F.MAIN_FORMATS))
def test_lut_matmul_paper_formats(fmt):
    cb = jnp.asarray(REG[fmt].padded())
    x, codes, scales = _case(7, 64, 256, 96, 64)
    got = lut_matmul(x, codes, scales, cb, block=64)
    want = ref.lut_matmul(x, codes, scales, cb, block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_lut_matmul_tile_boundaries():
    # shapes straddling the 128-tile boundary exercise ragged-edge masking
    for m, k, n in [(127, 128, 129), (128, 128, 128), (129, 256, 127),
                    (1, 128, 1), (256, 384, 256)]:
        x, codes, scales = _case(m * 7 + n, m, k, n, 128)
        cb = jnp.asarray(REG["sf4"].padded())
        got = lut_matmul(x, codes, scales, cb, block=128)
        want = ref.lut_matmul(x, codes, scales, cb, block=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_dequant_zero_codes_give_zero():
    """Code pointing at the codebook's zero entry must reconstruct 0 exactly
    (paper Section 3.3: lossless zero)."""
    cb = REG["sf4"].padded()
    zero_idx = int(np.where(cb == 0.0)[0][0])
    codes = jnp.full((64, 8), zero_idx, dtype=jnp.int32)
    scales = jnp.full((1, 8), 3.7, dtype=jnp.float32)
    w = ref.dequant(codes, scales, jnp.asarray(cb), block=64)
    assert np.all(np.asarray(w) == 0.0)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 300),
    k=st.integers(1, 300),
    fmt=st.sampled_from(list(F.MAIN_FORMATS)),
)
def test_act_quant_matches_ref(seed, m, k, fmt):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((m, k)) *
                     rng.uniform(0.01, 10)).astype(np.float32))
    cb = jnp.asarray(REG[fmt].padded())
    got = act_quant(x, cb)
    want = ref.act_quant(x, cb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_act_quant_zero_row():
    """An all-zero token must survive (scale guard, no NaN)."""
    x = jnp.zeros((4, 32), jnp.float32)
    cb = jnp.asarray(REG["nf4"].padded())
    y = np.asarray(act_quant(x, cb))
    assert np.all(y == 0.0)


def test_act_quant_idempotent():
    """Quantizing an already-quantized tensor is a fixed point."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32))
    cb = jnp.asarray(REG["sf4"].padded())
    y1 = act_quant(x, cb)
    y2 = act_quant(y1, cb)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-6)


def test_act_quant_values_land_on_codebook():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((16, 128)).astype(np.float32))
    cb_arr = REG["int4"].padded()
    y = np.asarray(act_quant(x, jnp.asarray(cb_arr)))
    absmax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    scale = absmax / np.max(np.abs(cb_arr))
    yn = y / scale
    # every normalized output must be (almost) a codebook entry
    d = np.min(np.abs(yn[..., None] - cb_arr[None, None]), axis=-1)
    assert np.max(d) < 1e-5
