"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps the Pallas kernels
(interpret=True) against these over shapes/blocks/codebooks, and the L2 model
can be built against either implementation (`use_pallas` flag) so any model
mismatch isolates to the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def dequant(codes: jnp.ndarray, scales: jnp.ndarray,
            codebook: jnp.ndarray, block: int) -> jnp.ndarray:
    """Reconstruct f32 weights from 4-bit codes + sub-channel scales.

    codes    : int8/int32 [K, N] -- indices into ``codebook``
    scales   : f32 [K//block, N] -- one scale per (block of K) x (column)
    codebook : f32 [16] -- the datatype's value set (padded, normalized)
    returns  : f32 [K, N]
    """
    vals = codebook[codes]  # [K, N]
    s = jnp.repeat(scales, block, axis=0)  # [K, N]
    return vals * s


def lut_matmul(x: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray,
               codebook: jnp.ndarray, block: int) -> jnp.ndarray:
    """x [M, K] @ dequant(codes, scales)[K, N] -> [M, N]."""
    w = dequant(codes, scales, codebook, block)
    return x @ w


def act_quant(x: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize activations per-row (per-token) against ``codebook``.

    The scale maps each row's absmax onto the codebook's max magnitude
    (which is 1 for normalized codebooks). Nearest-value rounding uses the
    sorted codebook's midpoints, identical to the Rust RTN quantizer.
    """
    cbmax = jnp.max(jnp.abs(codebook))
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / cbmax, 1.0)
    xn = x / scale
    mid = (codebook[1:] + codebook[:-1]) * 0.5  # [15]
    idx = jnp.sum(xn[..., None] > mid, axis=-1)  # [..., K] in 0..15
    return codebook[idx] * scale
