"""Pallas kernel: fused LUT-dequantize -> sub-channel rescale -> matmul.

This is the paper's compute hot-spot re-thought for TPU (DESIGN.md
SHardware-Adaptation): the original systems run CUDA LUT kernels / custom MAC
arrays; here the 16-entry codebook is runtime data held in VMEM, tiles are
BlockSpec'd to MXU-friendly shapes so the dequantized tile feeds the systolic
array, and HBM->VMEM traffic is codes (int8-held 4-bit) + per-block scales
rather than dequantized f32.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so lowering stays plain-HLO (see /opt/xla-example/README.md).
Real-TPU efficiency is estimated analytically in DESIGN.md SPerf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tile defaults. K is kept whole per tile (our model dims are
# <= ~1.5k) so each grid cell performs one full dot-product panel; the scale
# tile then covers all K/block rows of the scale matrix.
TILE_M = 128
TILE_N = 128


def _lut_matmul_kernel(x_ref, codes_ref, scales_ref, cb_ref, o_ref, *,
                       block: int):
    """One (TILE_M, TILE_N) output tile.

    x_ref      : f32 [TILE_M, K]
    codes_ref  : i32 [K, TILE_N]
    scales_ref : f32 [K // block, TILE_N]
    cb_ref     : f32 [16]          (the datatype, runtime data)
    o_ref      : f32 [TILE_M, TILE_N]
    """
    codes = codes_ref[...]
    cb = cb_ref[...]
    # LUT gather: one take per weight element, then one fma for the scale.
    vals = jnp.take(cb, codes, axis=0)  # [K, TILE_N]
    scales = scales_ref[...]
    w = vals * jnp.repeat(scales, block, axis=0)
    # MXU op: dense f32 dot on the dequantized tile.
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def lut_matmul(x: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray,
               codebook: jnp.ndarray, block: int = 128) -> jnp.ndarray:
    """x [M, K] @ (codebook[codes] * scales) [K, N] -> f32 [M, N].

    Shapes need not be tile-multiples; grid sizes use ceil-division and
    Pallas masks the ragged edges.
    """
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (k, k2)
    assert k % block == 0, (k, block)
    assert scales.shape == (k // block, n), (scales.shape, k, block, n)

    tm, tn = min(TILE_M, m), min(TILE_N, n)
    grid = (pl.cdiv(m, tm), pl.cdiv(n, tn))
    return pl.pallas_call(
        functools.partial(_lut_matmul_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((k // block, tn), lambda i, j: (0, j)),
            pl.BlockSpec((16,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, codes.astype(jnp.int32), scales, codebook)


def _act_quant_kernel(x_ref, cb_ref, o_ref):
    """Fake-quantize one row-tile of activations against the codebook."""
    x = x_ref[...]
    cb = cb_ref[...]
    cbmax = jnp.max(jnp.abs(cb))
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / cbmax, 1.0)
    xn = x / scale
    mid = (cb[1:] + cb[:-1]) * 0.5
    idx = jnp.sum(xn[..., None] > mid, axis=-1)
    o_ref[...] = jnp.take(cb, idx, axis=0) * scale


@jax.jit
def act_quant(x: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Per-row (per-token) activation fake-quant; W4A4 path. [M, K]->[M, K]."""
    m, k = x.shape
    tm = min(TILE_M, m)
    return pl.pallas_call(
        _act_quant_kernel,
        grid=(pl.cdiv(m, tm),),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(x, codebook)
