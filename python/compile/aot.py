"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

Run once at build time (`make artifacts`); Python never sits on the request
path. HLO text (not `.serialize()`) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Every artifact gets a sibling `<name>.params.txt` manifest that the Rust
loader uses to marshal inputs:  lines of `<param-name> <dtype> <d0,d1,...>`
in exact parameter order, then `-- outputs --` and the output descriptors.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import formats
from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(dtype: str):
    return {"f32": jnp.float32, "i8": jnp.int8, "i32": jnp.int32}[dtype]


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), _dt(dtype))


class Artifact:
    """One lowered graph: fn + ordered input specs + output names."""

    def __init__(self, name, fn, inputs, output_names):
        self.name = name
        self.fn = fn
        self.inputs = inputs  # list of (name, shape, dtype)
        self.output_names = output_names

    def emit(self, outdir: str) -> None:
        specs = [_spec(shape, dtype) for _, shape, dtype in self.inputs]
        lowered = jax.jit(self.fn).lower(*specs)
        text = to_hlo_text(lowered)
        if "custom-call" in text and "Mosaic" in text:
            raise RuntimeError(f"{self.name}: Mosaic custom-call leaked into "
                               "HLO; pallas must be interpret=True")
        path = os.path.join(outdir, f"{self.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        # Output shapes from the lowered signature.
        out_avals = jax.eval_shape(self.fn, *specs)
        flat, _ = jax.tree_util.tree_flatten(out_avals)
        with open(os.path.join(outdir, f"{self.name}.params.txt"), "w") as f:
            for name, shape, dtype in self.inputs:
                dims = ",".join(str(d) for d in shape)
                f.write(f"{name} {dtype} {dims}\n")
            f.write("-- outputs --\n")
            for oname, aval in zip(self.output_names, flat):
                dt = {np.dtype("float32"): "f32", np.dtype("int32"): "i32",
                      np.dtype("int8"): "i8"}[np.dtype(aval.dtype)]
                dims = ",".join(str(d) for d in aval.shape)
                f.write(f"{oname} {dt} {dims}\n")
        print(f"  wrote {self.name}.hlo.txt ({len(text)} chars, "
              f"{len(self.inputs)} inputs)")


# ---------------------------------------------------------------------------
# LM artifacts
# ---------------------------------------------------------------------------


def lm_artifacts(cfg: M.ModelConfig) -> list[Artifact]:
    arts = []
    fp32_specs = [(n, s, "f32") for n, s in M.param_specs(cfg)]
    names_fp32 = [n for n, _ in M.param_specs(cfg)]

    def unflatten(names, args):
        return dict(zip(names, args))

    # --- fp32 eval (baselines) ---
    def fwd_fp32(tokens, *params):
        p = unflatten(names_fp32, params)
        return (M.lm_forward(cfg, p, tokens, quant=False, use_pallas=False),)

    arts.append(Artifact(
        f"lm_fwd_fp32_{cfg.name}", fwd_fp32,
        [("tokens", (cfg.batch_eval, cfg.seq), "i32")] + fp32_specs,
        ["logits"]))

    def loss_fp32(tokens, *params):
        p = unflatten(names_fp32, params)
        return M.lm_loss(cfg, p, tokens, quant=False, use_pallas=False)

    arts.append(Artifact(
        f"lm_loss_fp32_{cfg.name}", loss_fp32,
        [("tokens", (cfg.batch_eval, cfg.seq + 1), "i32")] + fp32_specs,
        ["nll_sum", "count"]))

    # --- quantized weight-only eval ---
    for w4a4, tag in ((False, ""), (True, "_w4a4")):
        qspecs = M.quant_param_specs(cfg, w4a4=w4a4)
        qnames = [n for n, _, _ in qspecs]

        def fwd_q(tokens, *params, _qn=qnames, _w=w4a4):
            p = unflatten(_qn, params)
            return (M.lm_forward(cfg, p, tokens, quant=True, w4a4=_w,
                                 use_pallas=True),)

        arts.append(Artifact(
            f"lm_fwd{tag}_{cfg.name}", fwd_q,
            [("tokens", (cfg.batch_eval, cfg.seq), "i32")] + qspecs,
            ["logits"]))

        def loss_q(tokens, *params, _qn=qnames, _w=w4a4):
            p = unflatten(_qn, params)
            return M.lm_loss(cfg, p, tokens, quant=True, w4a4=_w,
                             use_pallas=True)

        arts.append(Artifact(
            f"lm_loss{tag}_{cfg.name}", loss_q,
            [("tokens", (cfg.batch_eval, cfg.seq + 1), "i32")] + qspecs,
            ["nll_sum", "count"]))

    # --- fused train step ---
    def train(step, tokens, *pmv):
        n = len(names_fp32)
        p = unflatten(names_fp32, pmv[:n])
        m = unflatten(names_fp32, pmv[n:2 * n])
        v = unflatten(names_fp32, pmv[2 * n:])
        loss, p2, m2, v2 = M.train_step(cfg, p, m, v, step, tokens)
        outs = [loss]
        outs += [p2[k] for k in names_fp32]
        outs += [m2[k] for k in names_fp32]
        outs += [v2[k] for k in names_fp32]
        return tuple(outs)

    train_inputs = (
        [("step", (), "f32"),
         ("tokens", (cfg.batch_train, cfg.seq + 1), "i32")]
        + fp32_specs
        + [(f"m.{n}", s, "f32") for n, s in M.param_specs(cfg)]
        + [(f"v.{n}", s, "f32") for n, s in M.param_specs(cfg)]
    )
    out_names = (["loss"] + names_fp32 + [f"m.{n}" for n in names_fp32]
                 + [f"v.{n}" for n in names_fp32])
    arts.append(Artifact(f"lm_train_{cfg.name}", train, train_inputs,
                         out_names))
    return arts


# ---------------------------------------------------------------------------
# Classifier artifacts
# ---------------------------------------------------------------------------


def cls_artifacts(cfg: M.ClassifierConfig) -> list[Artifact]:
    arts = []
    fp32_specs = [(n, s, "f32") for n, s in M.cls_param_specs(cfg)]
    names = [n for n, _ in M.cls_param_specs(cfg)]
    n_in = cfg.image * cfg.image

    def fwd_fp32(x, *params):
        p = dict(zip(names, params))
        return (M.cls_forward(cfg, p, x, quant=False, use_pallas=False),)

    arts.append(Artifact(
        f"cls_fwd_fp32_{cfg.name}", fwd_fp32,
        [("x", (cfg.batch_eval, n_in), "f32")] + fp32_specs, ["logits"]))

    for w4a4, tag in ((False, ""), (True, "_w4a4")):
        qspecs = M.cls_quant_param_specs(cfg, w4a4=w4a4)
        qnames = [n for n, _, _ in qspecs]

        def fwd_q(x, *params, _qn=qnames, _w=w4a4):
            p = dict(zip(_qn, params))
            return (M.cls_forward(cfg, p, x, quant=True, w4a4=_w,
                                  use_pallas=True),)

        arts.append(Artifact(
            f"cls_fwd{tag}_{cfg.name}", fwd_q,
            [("x", (cfg.batch_eval, n_in), "f32")] + qspecs, ["logits"]))

    def train(step, x, labels, *pmv):
        n = len(names)
        p = dict(zip(names, pmv[:n]))
        m = dict(zip(names, pmv[n:2 * n]))
        v = dict(zip(names, pmv[2 * n:]))
        loss, p2, m2, v2 = M.cls_train_step(cfg, p, m, v, step, x, labels)
        return tuple([loss] + [p2[k] for k in names] + [m2[k] for k in names]
                     + [v2[k] for k in names])

    arts.append(Artifact(
        f"cls_train_{cfg.name}", train,
        [("step", (), "f32"), ("x", (cfg.batch_train, n_in), "f32"),
         ("labels", (cfg.batch_train,), "i32")]
        + fp32_specs
        + [(f"m.{n}", s, "f32") for n, s in M.cls_param_specs(cfg)]
        + [(f"v.{n}", s, "f32") for n, s in M.cls_param_specs(cfg)],
        ["loss"] + names + [f"m.{n}" for n in names]
        + [f"v.{n}" for n in names]))
    return arts


# ---------------------------------------------------------------------------
# Standalone kernel bench artifact (blocked path)
# ---------------------------------------------------------------------------


def kernel_artifacts() -> list[Artifact]:
    from compile.kernels import lut_matmul as K
    mm, kk, nn, blk = 256, 512, 512, 128

    def bench(x, codes, scales, cb):
        return (K.lut_matmul(x, codes.astype(jnp.int32), scales, cb,
                             block=blk),)

    return [Artifact(
        "lut_matmul_bench", bench,
        [("x", (mm, kk), "f32"), ("codes", (kk, nn), "i8"),
         ("scales", (kk // blk, nn), "f32"), ("codebook", (16,), "f32")],
        ["y"])]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="nano,micro,small,med,large")
    ap.add_argument("--only", default="", help="emit artifacts whose name contains this")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts: list[Artifact] = []
    for name in args.models.split(","):
        arts += lm_artifacts(M.ZOO[name])
    for cfg in M.CLS_ZOO.values():
        arts += cls_artifacts(cfg)
    arts += kernel_artifacts()

    emitted = []
    for a in arts:
        if args.only and args.only not in a.name:
            continue
        a.emit(args.out)
        emitted.append(a.name)

    formats.dump_tsv(os.path.join(args.out, "codebooks.tsv"))
    with open(os.path.join(args.out, "MANIFEST.txt"), "w") as f:
        for name in emitted:
            f.write(name + "\n")
        f.write("codebooks.tsv\n")
    print(f"emitted {len(emitted)} artifacts + codebooks.tsv -> {args.out}")


if __name__ == "__main__":
    main()
