"""Datatype zoo: every numeric format evaluated in the paper.

Each format is represented as a *codebook*: the sorted list of representable
values, normalized so that max |v| == 1.  Quantization of a tensor block is
then `deq = s * nearest(codebook, x / s)` with a scale `s` chosen per block
(absmax or MSE-searched).  This uniform "lookup" view is exactly how the
paper treats all formats (Table 15 lists each format's value set) and lets a
single compiled artifact serve every format: the codebook is runtime data.

Lookup formats (NF4/SF4/NF3/SF3) are derived with Algorithm 1 of the paper;
hardened formats (INT, E2M1 variants, E3M0, E2M0, APoT4 variants) enumerate
their bit patterns.  Golden values: paper Table 15.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np
from scipy import stats

# ---------------------------------------------------------------------------
# Algorithm 1: quantile-derived lookup formats (NF-k, SF-k)
# ---------------------------------------------------------------------------


def _algorithm1(quantile, n_values: int) -> np.ndarray:
    """Paper Algorithm 1, generalized to ``n_values`` levels.

    Produces ``n_values`` codes: ``ceil(n/2)`` on the negative side and the
    rest (one more) on the positive side, sharing an exact zero at p = 1/2.
    The probability offset follows QLoRA: delta = (1/(2n) + 1/(2(n-1))) / 2.
    """
    if n_values < 4:
        raise ValueError("need at least 4 levels")
    delta = 0.5 * (1.0 / (2 * n_values) + 1.0 / (2 * (n_values - 1)))
    n_neg = n_values // 2  # values at p in [delta, 1/2], rightmost is zero
    n_pos = n_values - n_neg + 1  # values at p in [1/2, 1-delta], first is zero
    p_neg = np.linspace(delta, 0.5, n_neg)
    p_pos = np.linspace(0.5, 1.0 - delta, n_pos)
    q = np.concatenate([quantile(p_neg), quantile(p_pos)[1:]])
    q[n_neg - 1] = 0.0  # p = 1/2 maps to exactly zero
    return q / np.max(np.abs(q))


def normal_float(bits: int = 4) -> np.ndarray:
    """NF-k: Algorithm 1 with the standard-normal quantile (QLoRA's NF4)."""
    return _algorithm1(stats.norm.ppf, 2**bits)


def student_float(nu: float = 5.0, bits: int = 4) -> np.ndarray:
    """SF-k(nu): Algorithm 1 with the Student-t quantile. Paper Section 3.3."""
    return _algorithm1(lambda p: stats.t.ppf(p, nu), 2**bits)


# ---------------------------------------------------------------------------
# Integer formats
# ---------------------------------------------------------------------------


def int_format(bits: int) -> np.ndarray:
    """Symmetric two's-complement integers -2^(b-1) .. 2^(b-1)-1, normalized."""
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    v = np.arange(lo, hi + 1, dtype=np.float64)
    return v / np.max(np.abs(v))


# ---------------------------------------------------------------------------
# Minifloat formats (E-e M-m, with named industry variants)
# ---------------------------------------------------------------------------


def _minifloat_magnitudes(exp_bits: int, man_bits: int, bias: int,
                          subnormals: bool = True) -> list[float]:
    """All non-negative magnitudes of a sign+exp+mantissa minifloat.

    No inf/nan encodings at these widths (everything is a finite value), as
    in all the paper's FP4 variants.
    """
    mags = [0.0]
    n_exp = 2**exp_bits
    n_man = 2**man_bits
    for e in range(n_exp):
        for m in range(n_man):
            if e == 0:
                if not subnormals:
                    continue
                # subnormal: m/2^man * 2^(1-bias)
                val = (m / n_man) * 2.0 ** (1 - bias)
            else:
                val = (1.0 + m / n_man) * 2.0 ** (e - bias)
            if val != 0.0:
                mags.append(val)
    return sorted(set(mags))


def _signed(mags: list[float], extra_pos: list[float] = ()) -> np.ndarray:
    """Mirror magnitudes to a signed codebook, append supernormal extras.

    Supernormal extras are *positive-side only*: they reassign the redundant
    negative-zero bit pattern (paper Section 3.5), matching SF4's asymmetry.
    """
    pos = sorted(set(list(mags) + list(extra_pos)))
    neg = [-v for v in mags if v != 0.0]
    v = np.array(sorted(neg) + pos, dtype=np.float64)
    return v / np.max(np.abs(v))


def e2m1(variant: str = "base") -> np.ndarray:
    """E2M1 FP4 and its variants.

    base : +-{0, .5, 1, 1.5, 2, 3, 4, 6}          (15 values; +-0 redundancy)
    i    : Intel neural-compressor scaling, subnormal at 1/16 of min normal
    b    : bitsandbytes scaling (doubled range, same tiny subnormal)
    ns   : no subnormal support
    sr   : super-range  — negative-zero code reassigned to +8 (edge point)
    sp   : super-precision — negative-zero code reassigned to +5 (gap fill)
    """
    base = _minifloat_magnitudes(2, 1, bias=1)  # 0,.5,1,1.5,2,3,4,6
    if variant == "base":
        return _signed(base)
    if variant == "sr":
        return _signed(base, extra_pos=[8.0])
    if variant == "sp":
        return _signed(base, extra_pos=[5.0])
    if variant == "ns":
        return _signed(_minifloat_magnitudes(2, 1, bias=1, subnormals=False))
    if variant == "i":
        # Intel: normals 1..6 like base but the sole subnormal collapses to
        # 1/16 = 0.0625 (paper Table 15 lists +-0.062 on the +-6 range).
        mags = [0.0, 0.0625] + [m for m in base if m >= 1.0]
        return _signed(mags)
    if variant == "b":
        # bitsandbytes: doubled normal range {2,3,4,6,8,12}, subnormal 1/16.
        mags = [0.0, 0.0625] + [2.0 * m for m in base if m >= 1.0]
        return _signed(mags)
    raise ValueError(f"unknown e2m1 variant: {variant}")


def e3m0() -> np.ndarray:
    """E3M0 FP4: pure powers of two +-{0, .25, .5, 1, 2, 4, 8, 16}."""
    return _signed(_minifloat_magnitudes(3, 0, bias=2))


def e2m0() -> np.ndarray:
    """E2M0 FP3: the only well-defined FP3 (paper Section 4.5): +-{0,1,2,4}."""
    return _signed(_minifloat_magnitudes(2, 0, bias=0))


# ---------------------------------------------------------------------------
# Additive Powers-of-Two (APoT)
# ---------------------------------------------------------------------------

APOT4_S1 = (0.0, 0.5, 0.25, 0.0625)  # {0, 2^-1, 2^-2, 2^-4}
APOT4_S2 = (0.0, 0.125)  # {0, 2^-3}


def apot_from_sets(*sets: tuple[float, ...],
                   extra_pos: tuple[float, ...] = ()) -> np.ndarray:
    """General APoT: all sums taking one element per set, mirrored to signed."""
    sums = {0.0}
    acc = [0.0]
    for s in sets:
        acc = [a + b for a in acc for b in s]
    mags = sorted(set(round(a, 12) for a in acc))
    mx = max(mags)
    mags = [m / mx for m in mags]
    return _signed(mags, extra_pos=[e for e in extra_pos])


def apot4(variant: str = "base") -> np.ndarray:
    """APoT4 `2S (3)` variant of the paper: S1={0,2^-1,2^-2,2^-4}, S2={0,2^-3}.

    Magnitudes {0,.1,.2,.3,.4,.6,.8,1}; `sp` adds 0.5 (paper Table 15 +SP).
    """
    if variant == "base":
        return apot_from_sets(APOT4_S1, APOT4_S2)
    if variant == "sp":
        return apot_from_sets(APOT4_S1, APOT4_S2, extra_pos=(0.5,))
    raise ValueError(f"unknown apot4 variant: {variant}")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FormatSpec:
    """A named quantization datatype: codebook + hardware metadata."""

    name: str
    codebook: tuple[float, ...]
    bits: int
    family: str  # lookup | int | float | apot
    #: (exp_bits, man_bits) for minifloats, None otherwise
    fp_split: tuple[int, int] | None = None

    @property
    def n_values(self) -> int:
        return len(self.codebook)

    def as_array(self) -> np.ndarray:
        return np.array(self.codebook, dtype=np.float64)

    def padded(self, n: int = 16) -> np.ndarray:
        """Codebook padded to length ``n`` by repeating the max value.

        The compiled artifacts take a fixed-size f32[16] codebook input;
        padding with duplicates of the top value never changes nearest-value
        quantization results.
        """
        cb = self.as_array()
        if len(cb) > n:
            raise ValueError(f"{self.name}: codebook longer than {n}")
        pad = np.full(n - len(cb), cb[-1])
        return np.concatenate([cb, pad]).astype(np.float32)


def _mk(name, arr, bits, family, fp_split=None) -> FormatSpec:
    return FormatSpec(name, tuple(round(float(v), 10) for v in arr), bits,
                      family, fp_split)


def registry() -> dict[str, FormatSpec]:
    """All formats used in the paper's evaluation plus profiling extras."""
    r = {}

    def add(spec: FormatSpec):
        r[spec.name] = spec

    add(_mk("nf4", normal_float(4), 4, "lookup"))
    add(_mk("nf3", normal_float(3), 3, "lookup"))
    for nu in (3, 4, 5, 6, 7, 8, 10, 20):
        add(_mk(f"sf4_v{nu}", student_float(nu, 4), 4, "lookup"))
    add(_mk("sf4", student_float(5.0, 4), 4, "lookup"))  # the paper's SF4
    add(_mk("sf3", student_float(5.0, 3), 3, "lookup"))
    add(_mk("int3", int_format(3), 3, "int"))
    add(_mk("int4", int_format(4), 4, "int"))
    add(_mk("int5", int_format(5), 5, "int"))
    add(_mk("int8", int_format(8), 8, "int"))
    add(_mk("e2m1", e2m1("base"), 4, "float", (2, 1)))
    add(_mk("e2m1_i", e2m1("i"), 4, "float", (2, 1)))
    add(_mk("e2m1_b", e2m1("b"), 4, "float", (2, 1)))
    add(_mk("e2m1_ns", e2m1("ns"), 4, "float", (2, 1)))
    add(_mk("e2m1_sr", e2m1("sr"), 4, "float", (2, 1)))
    add(_mk("e2m1_sp", e2m1("sp"), 4, "float", (2, 1)))
    add(_mk("e3m0", e3m0(), 4, "float", (3, 0)))
    add(_mk("e2m0", e2m0(), 3, "float", (2, 0)))
    add(_mk("apot4", apot4("base"), 4, "apot"))
    add(_mk("apot4_sp", apot4("sp"), 4, "apot"))
    return r


#: The 11 datatypes of the paper's main evaluation (Tables 3-8, Fig. 3).
MAIN_FORMATS = (
    "nf4", "sf4", "int4", "e2m1_i", "e2m1_b", "e2m1", "e2m1_sr", "e2m1_sp",
    "e3m0", "apot4", "apot4_sp",
)


def dump_tsv(path: str) -> None:
    """Write every codebook as TSV (consumed by the Rust cross-check test)."""
    reg = registry()
    with open(path, "w") as f:
        f.write("# name\tbits\tfamily\tvalues...\n")
        for name in sorted(reg):
            s = reg[name]
            vals = "\t".join(f"{v:.10f}" for v in s.codebook)
            f.write(f"{name}\t{s.bits}\t{s.family}\t{vals}\n")


if __name__ == "__main__":
    for name, spec in sorted(registry().items()):
        print(f"{name:10s} [{spec.n_values:2d}] " +
              " ".join(f"{v:+.3f}" for v in spec.codebook))
