"""L2: JAX model definitions — decoder-only transformer LM + classifiers.

Three graph families, all AOT-lowered to HLO text by `aot.py`:

  * fp32 train step (fwd+bwd+AdamW fused) — used by the Rust driver to train
    the model zoo on synthetic corpora (the E2E example);
  * quantized-weight eval graphs (`fwd` -> logits, `loss` -> summed NLL):
    every quantized linear takes (codes i8, scales f32) + one shared 16-entry
    codebook, so the *datatype is runtime data* and a single artifact serves
    all formats in the paper;
  * W4A4 variants that additionally fake-quantize activations in-graph
    (per-token absmax) and accept per-linear SmoothQuant vectors.

Weight layout convention: all linear weights are [in, out] ("K x N"), matching
the lut_matmul kernel. Sub-channel block structure is applied by the Rust
quantizer, which expands per-block scales to per-row scales before upload;
the graph-level kernel therefore runs with block=1 while the blocked kernel
path is exercised by the standalone kernel artifact and the pytest sweeps
(DESIGN.md S6).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels import ref as kref
from compile.kernels import lut_matmul as kpallas


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only LM hyperparameters (one per zoo member)."""

    name: str
    vocab: int
    seq: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    batch_eval: int
    batch_train: int
    train_steps: int
    lr: float = 3e-3
    warmup: int = 20

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        total = 0
        for _, shape in param_specs(self):
            n = 1
            for s in shape:
                n *= s
            total += n
        return total


#: The model zoo. Role mapping to the paper's models is in DESIGN.md S2.
ZOO = {
    c.name: c
    for c in [
        ModelConfig("nano", 64, 32, 32, 2, 2, 128, 4, 16, 60),
        ModelConfig("micro", 128, 64, 64, 2, 4, 256, 8, 16, 300),
        ModelConfig("small", 128, 64, 128, 4, 4, 512, 8, 16, 300),
        ModelConfig("med", 128, 128, 256, 4, 8, 1024, 8, 8, 300),
        ModelConfig("large", 128, 128, 384, 6, 8, 1536, 8, 4, 200),
    ]
}

#: linear weights that get quantized (paper: every nn.Linear; lm_head and
#: embeddings stay fp32, as in neural-compressor's default).
QUANT_LINEARS = ("wq", "wk", "wv", "wo", "w1", "w2")


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Canonical (name, shape) list for the fp32 parameter flattening."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (v, d)),
        ("pos", (s, d)),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_g", (d,)),
            (f"l{i}.ln1_b", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_g", (d,)),
            (f"l{i}.ln2_b", (d,)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.w2", (f, d)),
        ]
    specs += [("lnf_g", (d,)), ("lnf_b", (d,)), ("head", (d, v))]
    return specs


def quant_param_specs(cfg: ModelConfig, w4a4: bool = False
                      ) -> list[tuple[str, tuple[int, ...], str]]:
    """(name, shape, dtype) list for the quantized-eval parameter set.

    Quantized linears are replaced by `<name>.codes` (i8 [K,N]) and
    `<name>.scales` (f32 [K,N], pre-expanded from sub-channel blocks).
    W4A4 adds a `<name>.smooth` inverse-SmoothQuant vector (f32 [K]).
    One shared `codebook` (+ `act_codebook` for W4A4) rides along.
    """
    out: list[tuple[str, tuple[int, ...], str]] = []
    for name, shape in param_specs(cfg):
        leaf = name.split(".")[-1]
        if leaf in QUANT_LINEARS:
            out.append((f"{name}.codes", shape, "i8"))
            out.append((f"{name}.scales", shape, "f32"))
            if w4a4:
                out.append((f"{name}.smooth", (shape[0],), "f32"))
        else:
            out.append((name, shape, "f32"))
    out.append(("codebook", (16,), "f32"))
    if w4a4:
        out.append(("act_codebook", (16,), "f32"))
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


def _linear(p, x, name, *, quant, w4a4, use_pallas):
    """Dense [.., K] @ [K, N]; quantized path goes through the L1 kernel."""
    if not quant:
        return x @ p[name]
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape((-1, k))
    if w4a4:
        x2 = x2 * p[f"{name}.smooth"][None, :]
        if use_pallas:
            x2 = kpallas.act_quant(x2, p["act_codebook"])
        else:
            x2 = kref.act_quant(x2, p["act_codebook"])
    codes = p[f"{name}.codes"].astype(jnp.int32)
    scales = p[f"{name}.scales"]
    if use_pallas:
        y = kpallas.lut_matmul(x2, codes, scales, p["codebook"], block=1)
    else:
        y = kref.lut_matmul(x2, codes, scales, p["codebook"], block=1)
    return y.reshape(lead + (y.shape[-1],))


def _attention(cfg: ModelConfig, p, x, i, *, quant, w4a4, use_pallas):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    kw = dict(quant=quant, w4a4=w4a4, use_pallas=use_pallas)
    q = _linear(p, x, f"l{i}.wq", **kw).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = _linear(p, x, f"l{i}.wk", **kw).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = _linear(p, x, f"l{i}.wv", **kw).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    return _linear(p, y, f"l{i}.wo", **kw)


def lm_forward(cfg: ModelConfig, p: dict, tokens: jnp.ndarray, *,
               quant: bool = False, w4a4: bool = False,
               use_pallas: bool = True) -> jnp.ndarray:
    """tokens i32 [B, S] -> logits f32 [B, S, V]."""
    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos"][None, :s]
    kw = dict(quant=quant, w4a4=w4a4, use_pallas=use_pallas)
    for i in range(cfg.n_layers):
        h = _layernorm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        x = x + _attention(cfg, p, h, i, **kw)
        h = _layernorm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        h = _linear(p, h, f"l{i}.w1", **kw)
        h = _gelu(h)
        x = x + _linear(p, h, f"l{i}.w2", **kw)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head"]


def lm_loss(cfg: ModelConfig, p: dict, tokens: jnp.ndarray, *,
            quant: bool = False, w4a4: bool = False,
            use_pallas: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens i32 [B, S+1] -> (summed next-token NLL, token count)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = lm_forward(cfg, p, inp, quant=quant, w4a4=w4a4,
                        use_pallas=use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll), jnp.float32(nll.size)


# ---------------------------------------------------------------------------
# Training (fp32, fused AdamW step)
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Scaled-normal init matching the Rust checkpoint loader's layout."""
    p = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        leaf = name.split(".")[-1]
        if leaf.endswith("_g"):
            p[name] = jnp.ones(shape, jnp.float32)
        elif leaf.endswith("_b"):
            p[name] = jnp.zeros(shape, jnp.float32)
        elif leaf in ("embed", "pos"):
            p[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            # Student-t(nu=5) init, matching the Rust trainer (DESIGN.md §2):
            # plants the heavy-tailed weight distribution of trained LLMs.
            std = (2.0 / shape[0] / (5.0 / 3.0)) ** 0.5
            p[name] = std * jax.random.t(sub, 5.0, shape, jnp.float32)
    return p


def _lr_schedule(cfg: ModelConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / max(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / max(cfg.train_steps - cfg.warmup, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def train_step(cfg: ModelConfig, p: dict, m: dict, v: dict,
               step: jnp.ndarray, tokens: jnp.ndarray):
    """One fused AdamW step. Returns (loss, p', m', v').

    Global-norm gradient clipping at 1.0; weight decay 0.01 on matrices.
    """

    def loss_fn(params):
        s, n = lm_loss(cfg, params, tokens, quant=False, use_pallas=False)
        return s / n

    loss, grads = jax.value_and_grad(loss_fn)(p)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))
    clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-6))
    lr = _lr_schedule(cfg, step)
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01
    t = step + 1.0
    p2, m2, v2 = {}, {}, {}
    for name in p:
        g = grads[name] * clip
        m2[name] = b1 * m[name] + (1 - b1) * g
        v2[name] = b2 * v[name] + (1 - b2) * jnp.square(g)
        mhat = m2[name] / (1 - b1**t)
        vhat = v2[name] / (1 - b2**t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        if p[name].ndim > 1:
            upd = upd + wd * p[name]
        p2[name] = p[name] - lr * upd
    return loss, p2, m2, v2


# ---------------------------------------------------------------------------
# Vision-role classifiers (Table 9): MLP and im2col CNN
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClassifierConfig:
    name: str
    kind: str  # "mlp" | "cnn"
    image: int = 16  # image side (1 channel)
    classes: int = 10
    hidden: int = 128
    channels: int = 16
    batch_eval: int = 64
    batch_train: int = 64
    train_steps: int = 400
    lr: float = 2e-3


CLS_ZOO = {
    c.name: c
    for c in [
        ClassifierConfig("mlp", "mlp"),
        ClassifierConfig("cnn", "cnn"),
    ]
}


def cls_param_specs(cfg: ClassifierConfig) -> list[tuple[str, tuple[int, ...]]]:
    n_in = cfg.image * cfg.image
    if cfg.kind == "mlp":
        return [
            ("fc1", (n_in, cfg.hidden)),
            ("b1", (cfg.hidden,)),
            ("fc2", (cfg.hidden, cfg.hidden)),
            ("b2", (cfg.hidden,)),
            ("fc3", (cfg.hidden, cfg.classes)),
            ("b3", (cfg.classes,)),
        ]
    # CNN: two 3x3 conv layers (as im2col matmuls) + global pool + fc.
    c = cfg.channels
    return [
        ("conv1", (9, c)),  # 3x3x1 -> c
        ("cb1", (c,)),
        ("conv2", (9 * c, c)),  # 3x3xc -> c
        ("cb2", (c,)),
        ("fc", (c, cfg.classes)),
        ("fcb", (cfg.classes,)),
    ]


CLS_QUANT = {"mlp": ("fc1", "fc2", "fc3"), "cnn": ("conv1", "conv2", "fc")}


def cls_quant_param_specs(cfg: ClassifierConfig, w4a4: bool = True
                          ) -> list[tuple[str, tuple[int, ...], str]]:
    out = []
    qnames = CLS_QUANT[cfg.kind]
    for name, shape in cls_param_specs(cfg):
        if name in qnames:
            out.append((f"{name}.codes", shape, "i8"))
            out.append((f"{name}.scales", shape, "f32"))
            if w4a4:
                out.append((f"{name}.smooth", (shape[0],), "f32"))
        else:
            out.append((name, shape, "f32"))
    out.append(("codebook", (16,), "f32"))
    if w4a4:
        out.append(("act_codebook", (16,), "f32"))
    return out


def _im2col(x, side, chans):
    """x [B, side*side*chans] -> patches [B*side*side, 9*chans] (pad=1)."""
    b = x.shape[0]
    img = x.reshape(b, side, side, chans)
    img = jnp.pad(img, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(img[:, dy:dy + side, dx:dx + side, :])
    pat = jnp.concatenate(cols, axis=-1)  # [B, side, side, 9*chans]
    return pat.reshape(b * side * side, 9 * chans)


def cls_forward(cfg: ClassifierConfig, p: dict, x: jnp.ndarray, *,
                quant: bool = False, w4a4: bool = False,
                use_pallas: bool = True) -> jnp.ndarray:
    """x f32 [B, image*image] -> logits [B, classes]."""
    kw = dict(quant=quant, w4a4=w4a4, use_pallas=use_pallas)
    if cfg.kind == "mlp":
        h = _gelu(_linear(p, x, "fc1", **kw) + p["b1"])
        h = _gelu(_linear(p, h, "fc2", **kw) + p["b2"])
        return _linear(p, h, "fc3", **kw) + p["b3"]
    b, side, c = x.shape[0], cfg.image, cfg.channels
    h = _im2col(x, side, 1)
    h = _gelu(_linear(p, h, "conv1", **kw) + p["cb1"])
    h = _im2col(h.reshape(b, -1), side, c)
    h = _gelu(_linear(p, h, "conv2", **kw) + p["cb2"])
    h = h.reshape(b, side * side, c).mean(axis=1)  # global average pool
    return _linear(p, h, "fc", **kw) + p["fcb"]


def cls_loss(cfg, p, x, labels, **kw):
    logits = cls_forward(cfg, p, x, **kw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def cls_init(cfg: ClassifierConfig, key: jax.Array) -> dict:
    p = {}
    for name, shape in cls_param_specs(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            p[name] = jnp.zeros(shape, jnp.float32)
        else:
            p[name] = (2.0 / shape[0]) ** 0.5 * jax.random.normal(
                sub, shape, jnp.float32)
    return p


def cls_train_step(cfg: ClassifierConfig, p: dict, m: dict, v: dict,
                   step: jnp.ndarray, x: jnp.ndarray, labels: jnp.ndarray):
    """One fused Adam step for the classifiers."""
    loss, grads = jax.value_and_grad(
        lambda q: cls_loss(cfg, q, x, labels, quant=False, use_pallas=False)
    )(p)
    b1, b2, eps = 0.9, 0.99, 1e-8
    t = step + 1.0
    p2, m2, v2 = {}, {}, {}
    for name in p:
        g = grads[name]
        m2[name] = b1 * m[name] + (1 - b1) * g
        v2[name] = b2 * v[name] + (1 - b2) * jnp.square(g)
        mhat = m2[name] / (1 - b1**t)
        vhat = v2[name] / (1 - b2**t)
        p2[name] = p[name] - cfg.lr * mhat / (jnp.sqrt(vhat) + eps)
    return loss, p2, m2, v2
