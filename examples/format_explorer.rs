//! Format explorer: the library's analytic API without any model — derive
//! Student Float for arbitrary degrees of freedom, inspect every codebook,
//! estimate MAC hardware cost, and measure reconstruction error on
//! t-distributed synthetic weights.
//!
//! ```sh
//! cargo run --release --offline --example format_explorer [nu]
//! ```

use anyhow::Result;
use llm_datatypes::distfit::profile_tensor;
use llm_datatypes::formats::{self, student_float};
use llm_datatypes::hw;
use llm_datatypes::quant::{quantize_weight, BlockSize, Calib, QuantConfig};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::tensor::Tensor;

fn main() -> Result<()> {
    let nu: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5.0);

    println!("== SF4 derivation (Algorithm 1) at nu = {nu} ==");
    let cb = student_float(nu, 4);
    println!("{}", cb.iter().map(|v| format!("{v:+.3}")).collect::<Vec<_>>().join(" "));

    println!("\n== hardware cost model (Table 10 machinery) ==");
    println!("{:<10} {:>6} {:>10} {:>9} {:>10}", "format", "accum", "MAC um2", "power uW", "overhead%");
    for name in hw::TABLE10_FORMATS {
        let a = hw::analyze(&formats::must(name)).unwrap();
        println!(
            "{:<10} {:>6} {:>10.1} {:>9.1} {:>10.2}",
            name,
            a.accum_bits,
            a.mac_area(),
            a.power,
            hw::overhead_pct(name).unwrap()
        );
    }

    println!("\n== reconstruction error on t(nu={nu}) weights, block 128 ==");
    let mut rng = Pcg64::new(42);
    let w = Tensor::new(&[512, 64], rng.student_t_vec(512 * 64, nu, 0.02));
    let prof = profile_tensor(w.data());
    println!(
        "planted nu={nu}; fitted nu={:.2}, KS-delta={:+.4} (t fits better when positive)",
        prof.t.nu,
        prof.ks_delta()
    );
    println!("{:<10} {:>12} {:>12}", "format", "MSE (None)", "MSE (MSE-clip)");
    for name in ["sf4", "nf4", "int4", "e2m1", "e2m1_sp", "e3m0", "apot4"] {
        let spec = formats::must(name);
        let mut errs = Vec::new();
        for calib in [Calib::None, Calib::Mse] {
            let q = quantize_weight(
                &w,
                &QuantConfig { format: spec.clone(), block: BlockSize::Sub(128), calib },
            );
            errs.push(w.sq_err(&q.dequant(&spec)) / w.len() as f64);
        }
        println!("{:<10} {:>12.3e} {:>12.3e}", name, errs[0], errs[1]);
    }
    println!("\n(SF4 should post the lowest MSE on heavy-tailed weights — the paper's thesis.)");
    Ok(())
}
