//! Quickstart: quantize a trained LM with Student Float (SF4) and compare
//! against NF4 / INT4 / fp32 on completion accuracy and perplexity.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use anyhow::Result;
use llm_datatypes::coordinator::model::{GraphKind, LmHandle};
use llm_datatypes::coordinator::pipeline::{fp32_values, quantize_lm, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, Session};
use llm_datatypes::exp::ensure_model;
use llm_datatypes::model_io::zoo;
use llm_datatypes::tasks::{completion_accuracy, perplexity};

fn main() -> Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let model = "micro";
    ensure_model(&session, model)?; // trains in ~20s if no checkpoint yet
    let cfg = zoo(model)?;
    let ckpt = session.load_checkpoint(model)?;
    let corpus = corpus_for(&cfg);
    let windows = corpus.heldout_windows(128, cfg.seq);

    println!("model `{model}`: {} params", cfg.n_params());
    println!("{:<8} {:>10} {:>10}", "format", "LAMB acc%", "Wiki ppl");

    // fp32 baseline
    let values = fp32_values(&cfg, &ckpt)?;
    let mut handle = LmHandle::bind(&session.engine, &cfg, GraphKind::Fp32, &values)?;
    let acc = completion_accuracy(&mut handle, &windows)?;
    let ppl = perplexity(&mut handle, &windows[..32])?;
    println!("{:<8} {:>10.2} {:>10.2}", "fp32", acc * 100.0, ppl);

    // quantized: the datatype is runtime data — same compiled artifact,
    // different 16-entry codebook + codes.
    for fmt in ["sf4", "nf4", "e2m1", "e2m1_sp", "int4"] {
        let pc = PipelineConfig::weight_only(fmt);
        let qm = quantize_lm(&cfg, &ckpt, &pc, &corpus)?;
        let mut handle =
            LmHandle::bind(&session.engine, &cfg, GraphKind::WeightOnly, &qm.values)?;
        let acc = completion_accuracy(&mut handle, &windows)?;
        let ppl = perplexity(&mut handle, &windows[..32])?;
        println!("{:<8} {:>10.2} {:>10.2}   (recon MSE {:.2e})", fmt, acc * 100.0, ppl, qm.recon_mse);
    }
    Ok(())
}
