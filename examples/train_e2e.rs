//! End-to-end driver (DESIGN.md §E2E): train a transformer LM from scratch
//! through the fused AOT train-step artifact, log the loss curve, then
//! post-training-quantize it across the paper's datatypes and report the
//! quality table — the full L1+L2+L3 stack on one real workload.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example train_e2e [--model med]
//! ```

use anyhow::Result;
use llm_datatypes::coordinator::model::{GraphKind, LmHandle};
use llm_datatypes::coordinator::pipeline::{fp32_values, quantize_lm, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::zoo;
use llm_datatypes::tasks::{completion_accuracy, perplexity};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("small");
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let cfg = zoo(model)?;
    let corpus = corpus_for(&cfg);

    println!("== E2E: training `{model}` ({} params) for {} steps ==", cfg.n_params(), cfg.train_steps);
    let t0 = std::time::Instant::now();
    let (ckpt, trace) =
        trainer::train_lm(&session.engine, &cfg, &corpus, cfg.train_steps, 0xE2E, 10)?;
    let train_secs = t0.elapsed().as_secs_f64();
    let first = trace.first().map(|(_, l)| *l).unwrap_or(f32::NAN);
    let last = trace.last().map(|(_, l)| *l).unwrap_or(f32::NAN);
    println!("loss {first:.3} -> {last:.3} in {train_secs:.1}s \
              ({:.2} steps/s)", cfg.train_steps as f64 / train_secs);

    std::fs::create_dir_all("results")?;
    let mut tsv = String::from("step\tloss\n");
    for (s, l) in &trace {
        tsv.push_str(&format!("{s}\t{l}\n"));
    }
    std::fs::write("results/e2e_loss_curve.tsv", tsv)?;

    println!("\n== PTQ across datatypes (weight-only, block 128) ==");
    let windows = corpus.heldout_windows(128, cfg.seq);
    println!("{:<10} {:>10} {:>10}", "format", "LAMB acc%", "Wiki ppl");
    let mut tsv = String::from("format\tlamb_acc\twiki_ppl\n");

    let values = fp32_values(&cfg, &ckpt)?;
    let mut handle = LmHandle::bind(&session.engine, &cfg, GraphKind::Fp32, &values)?;
    let acc0 = completion_accuracy(&mut handle, &windows)?;
    let ppl0 = perplexity(&mut handle, &windows[..32.min(windows.len())])?;
    println!("{:<10} {:>10.2} {:>10.2}", "fp32", acc0 * 100.0, ppl0);
    tsv.push_str(&format!("fp32\t{:.4}\t{:.4}\n", acc0, ppl0));

    for fmt in ["nf4", "sf4", "int4", "e2m1", "e2m1_sr", "e2m1_sp", "e3m0", "apot4", "apot4_sp"] {
        let pc = PipelineConfig::weight_only(fmt);
        let qm = quantize_lm(&cfg, &ckpt, &pc, &corpus)?;
        let mut handle =
            LmHandle::bind(&session.engine, &cfg, GraphKind::WeightOnly, &qm.values)?;
        let acc = completion_accuracy(&mut handle, &windows)?;
        let ppl = perplexity(&mut handle, &windows[..32.min(windows.len())])?;
        println!("{:<10} {:>10.2} {:>10.2}", fmt, acc * 100.0, ppl);
        tsv.push_str(&format!("{fmt}\t{acc:.4}\t{ppl:.4}\n"));
    }
    std::fs::write("results/e2e_ptq_table.tsv", tsv)?;
    println!("\nwrote results/e2e_loss_curve.tsv, results/e2e_ptq_table.tsv");
    Ok(())
}
