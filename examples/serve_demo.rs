//! Serving demo: a quantized LM behind the request router + dynamic
//! batcher, with a batch-1 vs batched throughput comparison — the
//! memory-bound serving scenario that motivates weight-only quantization.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serve_demo
//! ```

use std::time::{Duration, Instant};

use anyhow::Result;
use llm_datatypes::coordinator::model::{GraphKind, LmHandle};
use llm_datatypes::coordinator::pipeline::{quantize_lm, PipelineConfig};
use llm_datatypes::coordinator::serve::{run_loadgen, ServeConfig, Server};
use llm_datatypes::coordinator::{corpus_for, Session};
use llm_datatypes::exp::ensure_model;
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;

fn main() -> Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let model = "micro";
    ensure_model(&session, model)?;
    let cfg = zoo(model)?;
    let ckpt = session.load_checkpoint(model)?;
    let corpus = corpus_for(&cfg);

    let pc = PipelineConfig::weight_only("sf4");
    let qm = quantize_lm(&cfg, &ckpt, &pc, &corpus)?;

    let mut rng = Pcg64::new(3);
    let prompts: Vec<Vec<i32>> = (0..128)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 2].to_vec()
        })
        .collect();

    println!("serving `{model}` quantized to SF4 (batch capacity {})", cfg.batch_eval);
    for (label, clients, wait) in [
        ("batch=1 (no coalescing)", 1usize, Duration::from_micros(1)),
        ("dynamic batching, 16 clients", 16usize, Duration::from_millis(2)),
    ] {
        let handle =
            LmHandle::bind(&session.engine, &cfg, GraphKind::WeightOnly, &qm.values)?;
        let server =
            Server::new(handle, ServeConfig { max_wait: wait, max_requests: 0 });
        let t0 = Instant::now();
        let total = 128;
        let stats = run_loadgen(server, prompts.clone(), clients, total / clients)?;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "{label:32} served {:>4} in {secs:5.2}s = {:6.1} req/s | batches {:>3} \
             (fill {:.2}) | p50 {:?} p99 {:?}",
            stats.served,
            stats.served as f64 / secs,
            stats.batches,
            stats.mean_batch_fill,
            stats.p50_latency,
            stats.p99_latency
        );
    }
    Ok(())
}
