//! Serving demo: the continuous-batching decode engine generating
//! multi-token completions over a KV cache, streaming tokens per request —
//! the memory-bound autoregressive workload that motivates the paper's
//! weight-only 4-bit formats. Compares fp32 weights against SF4 fake-quant
//! on sustained decode, then shows one streamed generation up close.
//!
//! ```sh
//! cargo run --release --offline --example serve_demo
//! ```
//! (Runs the pure-Rust path: no AOT artifacts required. With no trained
//! checkpoint it serves a Student-t init and says so.)

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use llm_datatypes::coordinator::pipeline::{fake_quant_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{
    run_decode_loadgen, DecodeRequest, Engine, EngineConfig, SchedulerConfig, TokenEvent,
};

fn main() -> Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let model = "micro";
    let cfg = zoo(model)?;
    let ckpt = match session.load_checkpoint(model) {
        Ok(c) => c,
        Err(_) => {
            println!("(no trained checkpoint for `{model}`; using a Student-t init)");
            trainer::init_lm_params(&cfg, 0x5eed)
        }
    };
    let corpus = corpus_for(&cfg);

    let mut rng = Pcg64::new(3);
    let prompts: Vec<Vec<i32>> = (0..32)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 4].to_vec()
        })
        .collect();

    // -- sustained decode: fp32 vs SF4 fake-quant weights ------------------
    let slots = 8usize;
    let (clients, per_client, max_new) = (8usize, 2usize, 24usize);
    println!("continuous batching: {slots} KV slots, {clients} streaming clients, {max_new} tokens each");
    for format in ["fp32", "sf4"] {
        let weights = match format {
            "fp32" => ckpt.clone(),
            f => fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only(f), &corpus)?,
        };
        let mut engine = Engine::new(
            cfg,
            weights,
            EngineConfig {
                slots,
                scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        let report = run_decode_loadgen(&mut engine, &prompts, clients, per_client, max_new)?;
        println!("  {format:>5}: {report}");
    }

    // -- the same SF4 weights with a packed 4-bit KV cache -----------------
    let sf4_weights =
        fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)?;
    let mut engine = Engine::new(
        cfg,
        sf4_weights.clone(),
        EngineConfig {
            slots,
            kv_format: Some("sf4"),
            scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    );
    let report = run_decode_loadgen(&mut engine, &prompts, clients, per_client, max_new)?;
    println!("  sf4 weights + sf4 packed KV ({} KiB cache): {report}", engine.cache().bytes() / 1024);

    // -- one generation, streamed token by token ---------------------------
    let mut engine = Engine::new(cfg, sf4_weights, EngineConfig::default());
    let (req, events) = DecodeRequest::new(prompts[0].clone(), 16);
    println!("\nstreaming one SF4 generation (prompt {} tokens):", prompts[0].len());
    let (tx, rx) = mpsc::channel();
    tx.send(req).ok();
    drop(tx);
    let t0 = Instant::now();
    engine.run(rx)?;
    print!("  tokens:");
    for ev in events.try_iter() {
        match ev {
            TokenEvent::Token { token, .. } => print!(" {token}"),
            TokenEvent::Finished { reason, generated, .. } => {
                println!("\n  done: {generated} tokens ({reason:?}) in {:?}", t0.elapsed());
            }
            TokenEvent::Rejected { reason, .. } => println!("\n  rejected: {reason}"),
        }
    }
    Ok(())
}
